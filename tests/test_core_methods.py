"""Predictor, retry strategies, wastage metric, baselines.

Property tests use hypothesis when installed (see ``requirements-dev.txt``)
and a deterministic grid sweep otherwise.
"""

import numpy as np
import pytest

from repro.core import (
    AllocationPlan,
    DefaultMethod,
    KSegments,
    KSPlus,
    PPMImproved,
    TovarPPM,
    alloc_at,
    first_violation,
    ksplus_retry,
    simulate_execution,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _linear_traces(n=30, seed=0, noise=0.02):
    rng = np.random.default_rng(seed)
    Is, mems = [], []
    for _ in range(n):
        I = float(rng.uniform(1, 10))
        L = int(40 + 12 * I + rng.normal(0, 2))
        split = int(0.7 * L)
        m = np.concatenate([np.full(split, 1.5 + 0.4 * I),
                            np.full(L - split, 3.0 + 0.9 * I)])
        mems.append(m + rng.normal(0, noise, L))
        Is.append(I)
    return mems, [1.0] * n, Is


class TestKSPlusPredictor:
    def test_plan_monotone_and_offset(self):
        mems, dts, Is = _linear_traces()
        m = KSPlus(k=3)
        m.fit(mems, dts, Is)
        for I in (2.0, 5.0, 9.0):
            plan = m.predict(I)
            assert plan.starts[0] == 0.0
            assert np.all(np.diff(plan.starts) >= 0)
            assert plan.is_monotone()
            # +10% peak offset ⇒ predicted peak above the true final level
            assert plan.peaks[-1] > (3.0 + 0.9 * I) * 1.02

    def test_prediction_scales_with_input(self):
        mems, dts, Is = _linear_traces()
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        p_small, p_big = m.predict(2.0), m.predict(9.0)
        assert p_big.peaks[-1] > p_small.peaks[-1]
        assert p_big.starts[-1] > p_small.starts[-1]

    def test_runtime_prediction(self):
        mems, dts, Is = _linear_traces()
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        rt = m.predict_runtime(5.0)
        assert 40 + 60 * 0.7 < rt < 160


class TestRetry:
    def _plan(self):
        return AllocationPlan(starts=np.asarray([0.0, 100.0, 200.0]),
                              peaks=np.asarray([2.0, 4.0, 8.0]))

    def test_retime_before_last_segment(self):
        plan = self._plan()
        new = ksplus_retry(plan, t_fail=50.0, used=3.0)
        # next segment (idx 1) now starts exactly at the failure time
        assert np.isclose(new.starts[1], 50.0)
        assert np.isclose(new.starts[2], 100.0)  # scaled by same factor
        np.testing.assert_allclose(new.peaks, plan.peaks)  # peaks untouched

    def test_last_segment_bumps_peak(self):
        plan = self._plan()
        new = ksplus_retry(plan, t_fail=250.0, used=9.0)
        assert np.isclose(new.peaks[-1], 8.0 * 1.2)
        np.testing.assert_allclose(new.starts, plan.starts)

    def test_fail_at_zero(self):
        plan = self._plan()
        new = ksplus_retry(plan, t_fail=0.0, used=3.0)
        assert np.isclose(new.starts[1], 0.0)
        assert alloc_at(new, 0.0) >= 4.0  # allocation stepped up immediately

    def _check_retry_valid(self, t, used):
        new = ksplus_retry(self._plan(), t, used)
        assert new.starts[0] == 0.0
        assert np.all(np.diff(new.starts) >= 0)
        assert new.is_monotone()

    if HAVE_HYPOTHESIS:
        @given(t=st.floats(0, 300), used=st.floats(0.1, 20))
        @settings(max_examples=50, deadline=None)
        def test_retry_keeps_plan_valid(self, t, used):
            self._check_retry_valid(t, used)
    else:
        def test_retry_keeps_plan_valid(self):
            for t in np.linspace(0.0, 300.0, 26):
                for used in (0.1, 3.0, 9.0, 20.0):
                    self._check_retry_valid(float(t), used)


class TestWastage:
    def test_exact_value_flat(self):
        plan = AllocationPlan(starts=np.zeros(1), peaks=np.asarray([4.0]))
        mem = np.full(100, 3.0)
        res = simulate_execution(plan, lambda p, t, u: p, mem, 1.0)
        assert res.succeeded and res.num_retries == 0
        assert np.isclose(res.wastage_gbs, 100.0)

    def test_failed_attempt_counts_fully(self):
        plan = AllocationPlan(starts=np.zeros(1), peaks=np.asarray([2.0]))
        mem = np.concatenate([np.full(50, 1.0), np.full(50, 3.0)])

        def retry(p, t, u):
            return p.with_(peaks=np.asarray([3.5]))
        res = simulate_execution(plan, retry, mem, 1.0)
        assert res.succeeded and res.num_retries == 1
        # failed attempt: 51 samples * 2.0 allocated; success: 50*2.5 + 50*0.5
        assert np.isclose(res.wastage_gbs, 51 * 2.0 + 50 * 2.5 + 50 * 0.5)

    def test_unsatisfiable_demand(self):
        plan = AllocationPlan(starts=np.zeros(1), peaks=np.asarray([2.0]))
        mem = np.full(10, 500.0)
        res = simulate_execution(plan, lambda p, t, u: p, mem, 1.0,
                                 machine_memory=128.0)
        assert not res.succeeded

    def test_first_violation(self):
        plan = AllocationPlan(starts=np.asarray([0.0, 10.0]),
                              peaks=np.asarray([2.0, 5.0]))
        mem = np.asarray([1.0] * 5 + [4.0] * 10)
        assert first_violation(plan, mem, 1.0) == 5  # 4.0 > 2.0 in seg 0
        assert first_violation(plan, np.asarray([1.0] * 15), 1.0) == -1


class TestBaselines:
    def test_all_methods_protocol(self):
        mems, dts, Is = _linear_traces()
        test_mem = mems[0]
        methods = [KSPlus(k=3), KSegments(k=3), KSegments(k=3, variant="partial"),
                   TovarPPM(), PPMImproved(), DefaultMethod(limit_gb=16.0)]
        for m in methods:
            m.fit(mems, dts, Is)
            plan = m.predict(Is[0])
            res = simulate_execution(plan, m.retry, test_mem, 1.0,
                                     machine_memory=128.0)
            assert res.succeeded, m.name
            assert res.wastage_gbs >= 0

    def test_tovar_allocates_machine_on_failure(self):
        m = TovarPPM(machine_memory=64.0)
        m.fit(*_linear_traces(10))
        plan = m.predict(1.0)
        new = m.retry(plan, 5.0, 3.0)
        assert np.all(new.peaks == 64.0)

    def test_ppm_improved_doubles(self):
        m = PPMImproved(machine_memory=512.0)
        m.fit(*_linear_traces(10))
        plan = m.predict(1.0)
        new = m.retry(plan, 5.0, 3.0)
        np.testing.assert_allclose(new.peaks, plan.peaks * 2)

    def test_ksegments_equal_segments(self):
        mems, dts, Is = _linear_traces()
        m = KSegments(k=4)
        m.fit(mems, dts, Is)
        plan = m.predict(5.0)
        assert plan.n == 4
        gaps = np.diff(plan.starts)
        np.testing.assert_allclose(gaps, gaps[0], rtol=1e-6)  # equal sized

    def test_ksegments_selective_vs_partial(self):
        plan = AllocationPlan(starts=np.asarray([0.0, 10.0, 20.0]),
                              peaks=np.asarray([2.0, 4.0, 6.0]))
        sel = KSegments(k=3, variant="selective").retry(plan, 12.0, 5.0)
        par = KSegments(k=3, variant="partial").retry(plan, 12.0, 5.0)
        assert sel.peaks[1] > 4.0 and np.isclose(sel.peaks[2], 6.0)
        assert par.peaks[1] > 4.0 and par.peaks[2] >= par.peaks[1]
