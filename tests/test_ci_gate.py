"""The tier-1 floor gate (``benchmarks/ci_gate.py``).

This regex-over-pytest-output logic decides whether CI goes red; it
lived untested inline in ci.yml until PR 8.  The cases pin the exact
historical behavior (including the ``(\\d+) error`` regex matching both
"error" and "errors") plus the failure-shaped inputs the inline gate
never met: empty output, crash-before-summary, summary with only
failures.
"""

import pytest

from benchmarks.ci_gate import gate, main, parse_counts


class TestParseCounts:
    def test_clean_summary(self):
        c = parse_counts("392 passed in 578.67s (0:09:38)")
        assert c == {"passed": 392, "failed": 0, "errors": 0}

    def test_mixed_summary(self):
        c = parse_counts("3 failed, 380 passed, 2 errors in 60.00s")
        assert c == {"passed": 380, "failed": 3, "errors": 2}

    def test_singular_error(self):
        assert parse_counts("1 error in 2.1s")["errors"] == 1

    def test_empty_output_reads_as_zero(self):
        assert parse_counts("") == {"passed": 0, "failed": 0, "errors": 0}


class TestGate:
    def test_floor_met_passes(self):
        ok, msg = gate("392 passed in 10s", floor=375)
        assert ok and "OK" in msg and "392 passed" in msg

    def test_below_floor_fails_even_when_green(self):
        ok, msg = gate("100 passed in 10s", floor=375)
        assert not ok and "FAIL" in msg

    def test_any_failure_fails_above_floor(self):
        ok, _ = gate("1 failed, 500 passed in 10s", floor=375)
        assert not ok

    def test_any_error_fails_above_floor(self):
        ok, _ = gate("2 errors, 500 passed in 10s", floor=375)
        assert not ok

    def test_crash_before_summary_fails(self):
        ok, _ = gate("Traceback (most recent call last): ...", floor=1)
        assert not ok

    def test_floor_zero_still_blocks_failures(self):
        ok, _ = gate("5 failed in 1s", floor=0)
        assert not ok
        ok, _ = gate("no tests ran in 0.1s", floor=0)
        assert ok  # explicit floor of 0 with nothing broken


class TestMain:
    def test_exit_codes_from_file(self, tmp_path, capsys):
        report = tmp_path / "pytest.out"
        report.write_text("400 passed in 9s")
        assert main([str(report), "--floor", "375"]) == 0
        report.write_text("374 passed in 9s")
        assert main([str(report), "--floor", "375"]) == 1
        out = capsys.readouterr().out
        assert "tier-1 gate" in out

    def test_stdin_dash(self, monkeypatch):
        import io
        monkeypatch.setattr("sys.stdin", io.StringIO("380 passed"))
        assert main(["-", "--floor", "375"]) == 0

    def test_floor_is_required(self, tmp_path):
        report = tmp_path / "pytest.out"
        report.write_text("400 passed")
        with pytest.raises(SystemExit):
            main([str(report)])
