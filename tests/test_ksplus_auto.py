"""Beyond-paper extension: per-task automatic segment-count selection."""

import numpy as np
import pytest

from repro.core import KSPlus, KSPlusAuto, simulate_execution
from repro.core.ksplus import _resample_trace


def _two_phase_traces(n=24, seed=0):
    """Traces with 3 distinct plateaus — fixed k=2 under-segments them."""
    rng = np.random.default_rng(seed)
    mems, dts, Is = [], [], []
    for _ in range(n):
        I = float(rng.uniform(2, 8))
        a, b, c = int(20 + 8 * I), int(15 + 2 * I), int(10 + I)
        m = np.concatenate([
            np.full(a, 1.0 + 0.2 * I),
            np.full(b, 3.0 + 0.5 * I),
            np.full(c, 6.0 + 0.9 * I),
        ])
        mems.append(m + rng.normal(0, 0.01, len(m)))
        dts.append(1.0)
        Is.append(I)
    return mems, dts, Is


def test_auto_selects_sensible_k():
    mems, dts, Is = _two_phase_traces()
    auto = KSPlusAuto(candidates=(1, 2, 3, 4, 6))
    auto.fit(mems, dts, Is)
    assert auto.chosen_k is not None and auto.chosen_k >= 3  # 3 plateaus


def test_auto_not_worse_than_bad_fixed_k():
    mems, dts, Is = _two_phase_traces(seed=1)
    test_mems, test_dts, test_Is = _two_phase_traces(seed=2)

    def total_wastage(method):
        method.fit(mems, dts, Is)
        return sum(
            simulate_execution(method.predict(i), method.retry, m, d,
                               machine_memory=128.0).wastage_gbs
            for m, d, i in zip(test_mems, test_dts, test_Is))

    w_auto = total_wastage(KSPlusAuto(candidates=(1, 2, 3, 4, 6)))
    w_k1 = total_wastage(KSPlus(k=1))
    assert w_auto < w_k1  # k=1 is peak-only; auto must beat it here


def test_auto_protocol_compat():
    mems, dts, Is = _two_phase_traces(seed=3)
    auto = KSPlusAuto()
    auto.fit(mems, dts, Is)
    plan = auto.predict(5.0)
    assert plan.is_monotone()
    new = auto.retry(plan, t_fail=1.0, used=plan.peaks[0] * 2)
    assert new.n == plan.n
    assert auto.predict_runtime(5.0) > 0


def _hetero_dt_traces(seed=0):
    """Same workload as `_two_phase_traces`, but half the executions are
    sampled twice as fast (dt=0.5, duplicated samples) — identical
    envelopes over *time*, heterogeneous over *samples*."""
    mems, dts, Is = _two_phase_traces(seed=seed)
    for i in range(0, len(mems), 2):
        mems[i] = np.repeat(mems[i], 2)
        dts[i] = 0.5
    return mems, dts, Is


class TestHeterogeneousDt:
    def test_resample_branch_warns_and_selects(self):
        from repro.core.ksplus import reset_hetero_dt_warnings

        reset_hetero_dt_warnings()  # warnings dedupe per process
        mems, dts, Is = _hetero_dt_traces()
        auto = KSPlusAuto(candidates=(1, 2, 3, 4, 6))
        with pytest.warns(UserWarning, match="resampling"):
            auto.fit(mems, dts, Is)
        assert auto.chosen_k is not None and auto.chosen_k >= 3
        assert auto.predict(4.0).is_monotone()

    def test_oracle_branch_warns_and_matches_uniform_choice(self):
        from repro.core.ksplus import reset_hetero_dt_warnings

        reset_hetero_dt_warnings()
        mems, dts, Is = _hetero_dt_traces()
        auto = KSPlusAuto(candidates=(1, 2, 3, 4, 6), hetero_dt="oracle")
        with pytest.warns(UserWarning, match="oracle"):
            auto.fit(mems, dts, Is)
        # the two policies agree on this cleanly-separated workload
        resampled = KSPlusAuto(candidates=(1, 2, 3, 4, 6))
        with pytest.warns(UserWarning):
            resampled.fit(mems, dts, Is)
        assert auto.chosen_k == resampled.chosen_k

    def test_uniform_dt_does_not_warn(self):
        import warnings

        mems, dts, Is = _two_phase_traces(seed=4)
        auto = KSPlusAuto(candidates=(2, 3))
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            auto.fit(mems, dts, Is)

    def test_unknown_policy_raises(self):
        mems, dts, Is = _hetero_dt_traces()
        auto = KSPlusAuto(candidates=(2, 3), hetero_dt="bogus")
        with pytest.raises(ValueError, match="hetero_dt"):
            auto.fit(mems, dts, Is)

    def test_unknown_policy_raises_even_on_uniform_dt(self):
        """Config typos surface at fit time, not mid-experiment when the
        first mixed-dt family shows up."""
        mems, dts, Is = _two_phase_traces(seed=5)
        auto = KSPlusAuto(candidates=(2, 3), hetero_dt="resmaple")
        with pytest.raises(ValueError, match="hetero_dt"):
            auto.fit(mems, dts, Is)


class TestResampleTrace:
    def test_identity_when_dt_matches(self):
        m = np.arange(5.0)
        assert _resample_trace(m, 1.0, 1.0) is m

    def test_sample_and_hold_halving(self):
        m = np.asarray([1.0, 2.0, 3.0])
        out = _resample_trace(m, 1.0, 0.5)
        np.testing.assert_array_equal(out, [1, 1, 2, 2, 3, 3])

    def test_coarsening_keeps_duration(self):
        m = np.arange(10.0)
        out = _resample_trace(m, 0.5, 1.0)  # 5 s of trace at dt=1
        assert len(out) == 5
        np.testing.assert_array_equal(out, [0, 2, 4, 6, 8])
