"""Beyond-paper extension: per-task automatic segment-count selection."""

import numpy as np

from repro.core import KSPlus, KSPlusAuto, simulate_execution


def _two_phase_traces(n=24, seed=0):
    """Traces with 3 distinct plateaus — fixed k=2 under-segments them."""
    rng = np.random.default_rng(seed)
    mems, dts, Is = [], [], []
    for _ in range(n):
        I = float(rng.uniform(2, 8))
        a, b, c = int(20 + 8 * I), int(15 + 2 * I), int(10 + I)
        m = np.concatenate([
            np.full(a, 1.0 + 0.2 * I),
            np.full(b, 3.0 + 0.5 * I),
            np.full(c, 6.0 + 0.9 * I),
        ])
        mems.append(m + rng.normal(0, 0.01, len(m)))
        dts.append(1.0)
        Is.append(I)
    return mems, dts, Is


def test_auto_selects_sensible_k():
    mems, dts, Is = _two_phase_traces()
    auto = KSPlusAuto(candidates=(1, 2, 3, 4, 6))
    auto.fit(mems, dts, Is)
    assert auto.chosen_k is not None and auto.chosen_k >= 3  # 3 plateaus


def test_auto_not_worse_than_bad_fixed_k():
    mems, dts, Is = _two_phase_traces(seed=1)
    test_mems, test_dts, test_Is = _two_phase_traces(seed=2)

    def total_wastage(method):
        method.fit(mems, dts, Is)
        return sum(
            simulate_execution(method.predict(i), method.retry, m, d,
                               machine_memory=128.0).wastage_gbs
            for m, d, i in zip(test_mems, test_dts, test_Is))

    w_auto = total_wastage(KSPlusAuto(candidates=(1, 2, 3, 4, 6)))
    w_k1 = total_wastage(KSPlus(k=1))
    assert w_auto < w_k1  # k=1 is peak-only; auto must beat it here


def test_auto_protocol_compat():
    mems, dts, Is = _two_phase_traces(seed=3)
    auto = KSPlusAuto()
    auto.fit(mems, dts, Is)
    plan = auto.predict(5.0)
    assert plan.is_monotone()
    new = auto.retry(plan, t_fail=1.0, used=plan.peaks[0] * 2)
    assert new.n == plan.n
    assert auto.predict_runtime(5.0) > 0
