"""Method registry: specs, aliases, names, construction, offset tuning."""

import numpy as np
import pytest

from repro.core import (
    AllocationPlan,
    KSegments,
    KSPlus,
    RetrySpec,
    TovarFeedback,
    WittPercentile,
    registry,
)
from repro.core.envelope import OffsetCandidate
from repro.sched import ClusterSim, ElasticPlanner, Job, Node, evaluate_workflow
from repro.traces import eager


def _linear_traces(n=20, seed=0):
    rng = np.random.default_rng(seed)
    Is, mems = [], []
    for _ in range(n):
        I = float(rng.uniform(1, 8))
        L = int(30 + 10 * I)
        split = int(0.7 * L)
        m = np.concatenate([np.full(split, 1.0 + 0.3 * I),
                            np.full(L - split, 2.0 + 0.8 * I)])
        mems.append(m + rng.normal(0, 0.01, L))
        Is.append(I)
    return mems, [1.0] * n, Is


class TestRegistry:
    def test_round_trip_register_construct(self):
        """register → construct → alias → capability flags."""

        class Flat:
            def _fit(self, mems, dts, inputs):
                pass

        @registry.register_method(
            "test-flat", retry=RetrySpec("double"), cls=Flat,
            aliases=("tf-alias",), online=False, multi_segment=False)
        def _make(ctx):
            inst = Flat()
            inst.limit = ctx.default_limit
            return inst

        try:
            spec = registry.get_spec("test-flat")
            assert spec.retry == RetrySpec("double")
            assert not spec.online and not spec.multi_segment and spec.packed
            assert registry.canonical_name("tf-alias") == "test-flat"
            inst = registry.make("tf-alias", default_limit=4.0)
            assert isinstance(inst, Flat) and inst.limit == 4.0
            assert "test-flat" in registry.method_names()
        finally:
            registry.unregister_method("test-flat")
        assert "test-flat" not in registry.method_names()
        with pytest.raises(KeyError):
            registry.canonical_name("tf-alias")

    def test_duplicate_name_rejected(self):
        with pytest.raises(ValueError):
            @registry.register_method("ks+", retry=RetrySpec("none"),
                                      cls=KSPlus)
            def _dup(ctx):
                return KSPlus()

    def test_default_zoo_names(self):
        names = registry.method_names()
        for n in ("ks+", "ks+auto", "k-segments-selective", "tovar-ppm",
                  "tovar-feedback", "ppm-improved", "witt-p95", "default"):
            assert n in names

    def test_capability_flags(self):
        assert registry.get_spec("ks+").multi_segment
        assert not registry.get_spec("witt-p95").multi_segment
        # frozen paper baselines do not participate in online feedback
        assert not registry.get_spec("tovar-ppm").online
        assert not registry.get_spec("default").online
        assert registry.get_spec("tovar-feedback").online

    def test_instance_names_from_registry(self):
        """The registry is the single source of method names."""
        assert KSPlus().name == "ks+"
        assert KSegments(variant="partial").name == "k-segments-partial"
        assert KSegments(variant="selective").name == "k-segments-selective"
        assert WittPercentile().name == "witt-p95"
        assert WittPercentile(percentile=50).name == "witt-p50"
        assert TovarFeedback().name == "tovar-feedback"
        assert registry.make("default", default_limit=2.0).name == "default"

    def test_make_uses_context(self):
        m = registry.make("ks+", k=6)
        assert m.k == 6
        d = registry.make("default", default_limit=3.5)
        assert d.limit_gb == 3.5

    def test_resolve_passthrough(self):
        m = KSPlus(k=2)
        assert registry.resolve(m) is m
        assert isinstance(registry.resolve("witt"), WittPercentile)

    def test_retry_spec_lookup(self):
        assert registry.try_retry_spec("ks+") == RetrySpec("ksplus")
        assert registry.try_retry_spec("double") is None  # RetrySpec kind

    def test_capability_validation_at_resolve_time(self):
        """require= fails loudly at make/resolve, not deep in dispatch."""
        with pytest.raises(registry.MissingCapabilityError, match="online"):
            registry.make("tovar-ppm", require=("online",))
        with pytest.raises(registry.MissingCapabilityError, match="online"):
            registry.make("default", require=("online",))
        with pytest.raises(registry.MissingCapabilityError,
                           match="multi_segment"):
            registry.resolve("witt-p95", require=("multi_segment",))
        # the error names method and flag
        try:
            registry.make("default", require=("online",))
        except registry.MissingCapabilityError as e:
            assert e.method == "default" and e.flag == "online"
        # satisfied requirements construct normally
        m = registry.make("ks+", require=("online", "packed",
                                          "multi_segment"))
        assert isinstance(m, KSPlus)

    def test_capability_validation_on_instances(self):
        """Instances resolve back to their spec for the same checks."""
        m = registry.make("tovar-ppm")
        with pytest.raises(registry.MissingCapabilityError, match="online"):
            registry.resolve(m, require=("online",))
        assert registry.resolve(m, require=("packed",)) is m
        registry.check_capabilities("witt", require=("online",))  # alias ok
        with pytest.raises(ValueError, match="unknown capability flag"):
            registry.make("ks+", require=("bogus",))

    def test_capability_check_unregistered_instance(self):
        """Unregistered methods: only the structural packed check applies."""

        class Bare:
            pass

        registry.check_capabilities(Bare(), require=("online",))  # no spec
        with pytest.raises(registry.MissingCapabilityError, match="packed"):
            registry.check_capabilities(Bare(), require=("packed",))


class TestSimulatorIntegration:
    def test_method_result_names_canonical(self):
        """Aliases in the methods list resolve to canonical result names,
        and the per-family default limit is the family's real one."""
        res = evaluate_workflow(eager(8), seed=0, train_frac=0.5, k=3,
                                methods=["witt", "ksplus", "default"])
        assert set(res.methods) == {"witt-p95", "ks+", "default"}

    def test_default_methods_shim(self):
        from repro.sched import default_methods
        zoo = default_methods(3, 64.0, 5.0)
        assert list(zoo) == registry.method_names()
        d = zoo["default"]()
        assert d.limit_gb == 5.0 and d.machine_memory == 64.0
        assert zoo["ks+"]().k == 3


def _cluster_jobs(n=16):
    rng = np.random.default_rng(3)
    jobs = []
    for j in range(n):
        L = int(rng.integers(20, 40))
        split = int(0.6 * L)
        mem = np.concatenate([np.full(split, 2.0), np.full(L - split, 6.0)])
        plan = AllocationPlan(starts=np.asarray([0.0, split - 1.0]),
                              peaks=np.asarray([2.2, 6.5]))
        jobs.append(Job(jid=j, family="a" if j % 2 else "b", input_gb=1.0,
                        mem=mem, dt=1.0, plan=plan, est_runtime=float(L)))
    return jobs


class TestSchedulerRegistryNames:
    def test_cluster_retry_by_registry_name(self):
        r1 = ClusterSim([Node(0, 24.0)]).run(_cluster_jobs(), "ks+")
        r2 = ClusterSim([Node(0, 24.0)]).run(
            _cluster_jobs(), RetrySpec("ksplus"))
        assert r1.placements == r2.placements
        assert r1.total_wastage_gbs == r2.total_wastage_gbs

    def test_cluster_retry_by_method_object(self):
        m = KSPlus()
        r1 = ClusterSim([Node(0, 24.0)]).run(_cluster_jobs(), m)
        r2 = ClusterSim([Node(0, 24.0)]).run(
            _cluster_jobs(), RetrySpec("ksplus", bump=m.last_peak_bump))
        assert r1.placements == r2.placements

    def test_cluster_auto_offsets(self):
        """offsets='auto' returns the grid's lowest-wastage result."""
        sweep = ClusterSim([Node(0, 24.0)]).run(
            _cluster_jobs(), "ks+", offsets=list(registry.DEFAULT_OFFSET_GRID))
        best = ClusterSim([Node(0, 24.0)]).run(
            _cluster_jobs(), "ks+", offsets="auto")
        assert best.total_wastage_gbs == min(
            r.total_wastage_gbs for r in sweep)
        assert best.offset in registry.DEFAULT_OFFSET_GRID

    def test_cluster_per_family_offsets_identity(self):
        """An identity per-family mapping reproduces the base run."""
        base = ClusterSim([Node(0, 24.0)]).run(_cluster_jobs(), "ks+")
        ident = ClusterSim([Node(0, 24.0)]).run(
            _cluster_jobs(), "ks+",
            offsets={"a": OffsetCandidate(), "b": OffsetCandidate()})
        assert ident.placements == base.placements
        assert ident.total_wastage_gbs == base.total_wastage_gbs

    def test_cluster_per_family_offsets_differ(self):
        padded = ClusterSim([Node(0, 24.0)]).run(
            _cluster_jobs(), "ks+", offsets={"a": OffsetCandidate(peak=0.5)})
        base = ClusterSim([Node(0, 24.0)]).run(_cluster_jobs(), "ks+")
        assert padded.total_wastage_gbs != base.total_wastage_gbs

    def test_cluster_per_family_unknown_family_rejected(self):
        """A typo'd family key must fail loudly, not silently run at
        identity offsets."""
        with pytest.raises(ValueError, match="unknown families"):
            ClusterSim([Node(0, 24.0)]).run(
                _cluster_jobs(), "ks+",
                offsets={"nonexistent": OffsetCandidate(peak=0.1)})

    def test_cluster_per_family_bumps_may_differ(self):
        """PR 5: differing per-family last_peak_bump values fold into a
        per-lane bump array (NaN = spec default) instead of raising; the
        replay completes and records the per-lane candidate."""
        res = ClusterSim([Node(0, 24.0)]).run(
            _cluster_jobs(), "ks+",
            offsets={"a": OffsetCandidate(last_peak_bump=0.3),
                     "b": OffsetCandidate(last_peak_bump=0.5)})
        assert res.offset is not None
        bumps = np.asarray(res.offset.last_peak_bump)
        assert bumps.ndim == 1 and {0.3, 0.5} <= set(
            np.unique(bumps[~np.isnan(bumps)]))

    def test_elastic_admit_by_name_and_method(self):
        pl = ElasticPlanner()
        pl.node_join("s0", 16.0)
        assert pl.submit("j1", "default", 0.0, input_gb=2.0) == "s0"
        mems, dts, Is = _linear_traces()
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        assert pl.submit("j2", m, 0.0, input_gb=2.0) == "s0"
        with pytest.raises(ValueError):  # methods need an input size
            pl.admit("j3", "default", 0.0)


class TestOffsetTuning:
    def test_tune_offset_picks_grid_argmin(self):
        mems, dts, Is = _linear_traces()
        m = KSPlus(k=3)
        m.fit(mems, dts, Is)
        cands = (OffsetCandidate(), OffsetCandidate(peak=0.2),
                 OffsetCandidate(peak=-0.5))  # -50% forces OOM retries
        best, totals = registry.tune_offset(
            m, mems, dts, Is, candidates=cands, machine_memory=64.0)
        assert len(totals) == len(cands)
        assert best == cands[int(np.argmin(totals))]
        # severe under-allocation must never win the replay
        assert best != cands[2]

    def test_tune_offset_matches_oracle_totals(self):
        """Per-candidate totals equal one-job-at-a-time fleet replays."""
        from repro.core import simulate_fleet
        from repro.core.envelope import apply_offsets
        from repro.core.fleet import packed_predict
        mems, dts, Is = _linear_traces(n=12, seed=1)
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        cands = (OffsetCandidate(), OffsetCandidate(peak=-0.4,
                                                    last_peak_bump=0.6))
        _, totals = registry.tune_offset(
            m, mems, dts, Is, candidates=cands, machine_memory=32.0)
        starts, peaks, nseg = packed_predict(m, Is)
        for cand, tot in zip(cands, totals):
            st, pk = apply_offsets(starts, peaks, nseg, cand)
            spec = m.retry_spec
            if cand.last_peak_bump is not None:
                spec = spec._replace(bump=cand.last_peak_bump)
            fr = simulate_fleet(
                (st.astype(np.float32), pk.astype(np.float32), nseg),
                spec, mems, 1.0, machine_memory=32.0)
            assert tot == fr.total_gbs

    def test_tune_offset_rejects_hetero_dt(self):
        mems, dts, Is = _linear_traces(n=6)
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        with pytest.raises(ValueError):
            registry.tune_offset(m, mems, [1.0] * 5 + [2.0], Is)
