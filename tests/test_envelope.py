"""Unit tests for the shared packed-envelope layer (`repro.core.envelope`).

The packed functions are the single implementation of envelope arithmetic;
these tests pin them against brute-force per-sample / per-plan references
and against the 1-lane scalar views in `allocation` / `retry`.
"""

import numpy as np
import pytest

from repro.core import (
    AllocationPlan,
    PackedEnvelopes,
    RetrySpec,
    alloc_at,
    alloc_at_packed,
    first_violation,
    first_violation_packed,
    fits_under,
    residual_over,
    retry_packed,
    segment_sample_bounds,
    span_alloc_sum,
    usage_over,
)


def _random_plans(rng, B, kmax=6):
    plans = []
    for _ in range(B):
        n = int(rng.integers(1, kmax + 1))
        starts = np.sort(rng.uniform(0, 80, n))
        starts[0] = 0.0
        peaks = np.maximum.accumulate(rng.uniform(1, 16, n))
        plans.append(AllocationPlan(starts=starts, peaks=peaks))
    return plans


class TestPacking:
    def test_round_trip(self):
        rng = np.random.default_rng(0)
        plans = _random_plans(rng, 17)
        env = PackedEnvelopes.from_plans(plans)
        assert env.B == 17 and env.K == max(p.n for p in plans)
        for i, p in enumerate(plans):
            s, pk = env.row(i)
            assert np.array_equal(s, p.starts)
            assert np.array_equal(pk, p.peaks)

    def test_padded_rows_evaluate_identically(self):
        rng = np.random.default_rng(1)
        plans = _random_plans(rng, 9)
        env = PackedEnvelopes.from_plans(plans, k=10)
        t = rng.uniform(0, 120, 64)
        packed = alloc_at_packed(env.starts, env.peaks, t)
        for i, p in enumerate(plans):
            np.testing.assert_array_equal(packed[i], alloc_at(p, t))

    def test_too_many_segments_raises(self):
        p = AllocationPlan(np.asarray([0.0, 1.0]), np.asarray([1.0, 2.0]))
        with pytest.raises(ValueError):
            PackedEnvelopes.from_plans([p], k=1)


class TestAllocAndViolation:
    def test_per_lane_time_grids(self):
        rng = np.random.default_rng(2)
        plans = _random_plans(rng, 6)
        env = PackedEnvelopes.from_plans(plans)
        t = rng.uniform(0, 100, (6, 33))
        out = alloc_at_packed(env.starts, env.peaks, t)
        for i, p in enumerate(plans):
            np.testing.assert_array_equal(out[i], alloc_at(p, t[i]))

    def test_first_violation_matches_scalar(self):
        rng = np.random.default_rng(3)
        plans = _random_plans(rng, 24)
        env = PackedEnvelopes.from_plans(plans)
        T = 96
        mems = np.abs(rng.normal(6, 4, (24, T)))
        lengths = rng.integers(8, T + 1, 24)
        mems *= np.arange(T)[None, :] < lengths[:, None]
        viol = first_violation_packed(env.starts, env.peaks, mems,
                                      lengths, 0.7)
        for i, p in enumerate(plans):
            assert viol[i] == first_violation(p, mems[i, :lengths[i]], 0.7)


class TestSpanArithmetic:
    @pytest.mark.parametrize("dt", [1.0, 0.3, 2.5])
    def test_span_sum_equals_per_sample_sum(self, dt):
        rng = np.random.default_rng(4)
        plans = _random_plans(rng, 20)
        env = PackedEnvelopes.from_plans(plans)
        T = 128
        upto = rng.integers(1, T, 20)
        bounds = segment_sample_bounds(env.starts, dt)
        spans = span_alloc_sum(env.peaks, bounds, upto)
        for i, p in enumerate(plans):
            alloc = alloc_at(p, np.arange(upto[i]) * dt)
            np.testing.assert_allclose(spans[i], alloc.sum(), rtol=1e-12)

    def test_per_lane_dt(self):
        rng = np.random.default_rng(5)
        plans = _random_plans(rng, 8)
        env = PackedEnvelopes.from_plans(plans)
        dts = rng.uniform(0.2, 2.0, (8, 1))
        upto = rng.integers(1, 64, 8)
        bounds = segment_sample_bounds(env.starts, dts)
        spans = span_alloc_sum(env.peaks, bounds, upto)
        for i, p in enumerate(plans):
            alloc = alloc_at(p, np.arange(upto[i]) * dts[i, 0])
            np.testing.assert_allclose(spans[i], alloc.sum(), rtol=1e-12)


class TestResidual:
    def test_usage_matches_loop(self):
        rng = np.random.default_rng(6)
        plans = _random_plans(rng, 5)
        env = PackedEnvelopes.from_plans(plans)
        t0 = rng.uniform(0, 30, 5)
        dur = rng.uniform(10, 60, 5)
        t = rng.uniform(0, 120, 40)
        got = usage_over(env.starts, env.peaks, t0, t, dur)
        want = np.zeros_like(t)
        for i, p in enumerate(plans):
            rel = t - t0[i]
            active = (rel >= 0) & (rel < dur[i] + 1e-9)
            want += np.where(active, alloc_at(p, np.maximum(rel, 0.0)), 0.0)
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_no_window_counts_forever(self):
        p = AllocationPlan(np.zeros(1), np.asarray([4.0]))
        env = PackedEnvelopes.from_plans([p])
        t = np.asarray([0.0, 5.0, 500.0])
        np.testing.assert_array_equal(
            usage_over(env.starts, env.peaks, np.zeros(1), t), [4, 4, 4])
        np.testing.assert_array_equal(
            usage_over(env.starts, env.peaks, np.zeros(1), t,
                       dur=np.asarray([10.0])), [4, 4, 0])

    def test_empty_usage_and_fits(self):
        z = np.zeros((0, 3))
        t = np.linspace(0, 10, 8)
        assert usage_over(z, z, np.zeros(0), t).shape == t.shape
        resid = residual_over(32.0, z, z, np.zeros(0), t)
        need = np.full((2, 8), 30.0)
        np.testing.assert_array_equal(fits_under(need, resid), [True, True])
        np.testing.assert_array_equal(
            fits_under(need + 3.0, resid), [False, False])


class TestRetryPacked:
    """Batch path vs the 1-lane scalar views (which are pinned bitwise to
    the seed implementations by the fleet differential tests)."""

    @pytest.mark.parametrize("kind", ["ksplus", "kseg-selective",
                                      "kseg-partial", "double",
                                      "max-machine", "none"])
    def test_batch_matches_single_lane(self, kind):
        from repro.core.retry import apply_retry_spec
        rng = np.random.default_rng(7)
        plans = _random_plans(rng, 30)
        env = PackedEnvelopes.from_plans(plans)
        t_fail = rng.uniform(0, 100, 30)
        used = rng.uniform(1, 30, 30)
        spec = RetrySpec(kind)
        st, pk = retry_packed(spec, env.starts, env.peaks, env.nseg,
                              t_fail, used, machine_memory=64.0)
        for i, p in enumerate(plans):
            one = apply_retry_spec(spec, p, float(t_fail[i]), float(used[i]),
                                   machine_memory=64.0)
            np.testing.assert_array_equal(st[i, :p.n], one.starts)
            np.testing.assert_array_equal(pk[i, :p.n], one.peaks)

    def test_unknown_kind_raises(self):
        env = PackedEnvelopes.from_plans(
            [AllocationPlan(np.zeros(1), np.ones(1))])
        with pytest.raises(ValueError):
            retry_packed(RetrySpec("bogus"), env.starts, env.peaks,
                         env.nseg, [0.0], [1.0])

    def test_inputs_not_mutated(self):
        env = PackedEnvelopes.from_plans(
            _random_plans(np.random.default_rng(8), 4))
        s0, p0 = env.starts.copy(), env.peaks.copy()
        retry_packed(RetrySpec("ksplus"), env.starts, env.peaks, env.nseg,
                     np.full(4, 5.0), np.full(4, 9.0))
        np.testing.assert_array_equal(env.starts, s0)
        np.testing.assert_array_equal(env.peaks, p0)
