"""Substrate tests: optimizer, data pipeline, checkpointing, partitioning."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMDataset
from repro.launch.mesh import make_local_mesh
from repro.launch.partitioning import default_rules, spec_for
from repro.optim import adamw_init, adamw_update, cosine_schedule


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        opt = adamw_init(params)
        for _ in range(300):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(grads, opt, params, lr=0.05,
                                          weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clipping(self):
        params = {"w": jnp.ones(4)}
        opt = adamw_init(params)
        grads = {"w": jnp.full(4, 1e6)}
        _, _, stats = adamw_update(grads, opt, params, lr=0.1, clip_norm=1.0)
        assert float(stats["grad_norm"]) > 1e5
        assert float(stats["clip_scale"]) < 1e-4

    def test_weight_decay_only_matrices(self):
        params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones(2)}
        opt = adamw_init(params)
        grads = {"mat": jnp.zeros((2, 2)), "vec": jnp.zeros(2)}
        new, _, _ = adamw_update(grads, opt, params, lr=0.1, weight_decay=0.5)
        assert float(new["mat"][0, 0]) < 1.0    # decayed
        assert float(new["vec"][0]) == 1.0      # untouched

    def test_schedule(self):
        lr = cosine_schedule(peak_lr=1.0, warmup_steps=10, total_steps=100)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


class TestData:
    def test_deterministic(self):
        ds = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=8, seed=3)
        a, b = ds.batch(5), ds.batch(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch(6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_shards_disjoint_and_stable(self):
        ds = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=8, seed=0)
        s0 = ds.batch(1, shard=0, num_shards=2)
        s1 = ds.batch(1, shard=1, num_shards=2)
        assert s0["tokens"].shape[0] == 4
        assert not np.array_equal(s0["tokens"], s1["tokens"])
        # restart-stability: same (seed, step, shard) → same batch
        np.testing.assert_array_equal(
            s0["tokens"], ds.batch(1, shard=0, num_shards=2)["tokens"])

    def test_labels_are_next_tokens(self):
        ds = SyntheticLMDataset(vocab=50, seq_len=12, global_batch=2, seed=1)
        b = ds.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "nested": {"b": jnp.ones(4)}}
        mgr.save(10, tree, meta={"loss": 1.5})
        out = mgr.restore(10, tree)
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert mgr.meta(10)["loss"] == 1.5

    def test_keep_prunes(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"x": jnp.arange(8)}
        mgr.save_async(7, tree)
        mgr.wait()
        assert mgr.latest_step() == 7
        np.testing.assert_array_equal(mgr.restore(7, tree)["x"], tree["x"])

    def test_atomic_no_partial_dirs(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros(2)})
        names = os.listdir(tmp_path)
        assert all(not n.endswith(".tmp0") for n in names)


class TestPartitioning:
    def test_spec_resolution(self):
        mesh = make_local_mesh()  # (n,1) data/model
        rules = default_rules(mesh)
        n = mesh.shape["data"]
        spec = spec_for(("batch", None), (n * 4, 8), mesh, rules)
        assert spec[0] in ("data", ("data",))
        assert spec[1] is None

    def test_nondivisible_falls_back_to_replicated(self):
        mesh = make_local_mesh()
        rules = default_rules(mesh)
        spec = spec_for(("batch",), (1,), mesh, rules) \
            if mesh.shape["data"] > 1 else None
        if spec is not None:
            assert spec[0] is None  # 1 not divisible by data>1 → replicated

    def test_no_double_axis_use(self):
        mesh = make_local_mesh()
        rules = dict(default_rules(mesh))
        rules["x"] = "data"
        rules["y"] = "data"
        n = mesh.shape["data"]
        spec = spec_for(("x", "y"), (n * 2, n * 2), mesh, rules)
        used = [s for s in spec if s is not None]
        assert len(used) <= 1  # second mapping must be dropped

    def test_plan_mesh(self):
        from repro.sched import plan_mesh
        assert plan_mesh(256, (96, 28672)) == (16, 16)
        d, m = plan_mesh(192, (96, 28672))
        assert d * m == 192 and 96 % m == 0
