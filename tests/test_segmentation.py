"""Algorithm 1 (segmentation): properties + oracle/JAX equivalence.

The property tests run under hypothesis when it is installed (see
``requirements-dev.txt``); otherwise they fall back to a deterministic
seeded sweep so the suite stays meaningful on minimal environments.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_segments, get_segments_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _check_envelope_properties(M, k):
    S, P = get_segments_ref(M, k)
    # 1. at most k segments, durations cover the trace exactly
    assert 1 <= len(S) <= k
    assert S.sum() == len(M)
    assert np.all(S >= 1)
    # 2. peaks strictly increasing (monotone envelope)
    assert np.all(np.diff(P) > 0)
    # 3. the step function upper-bounds the trace (no task failure)
    bounds = np.repeat(P, S)
    assert np.all(M <= bounds + 1e-9)
    # 4. each segment's peak is attained (tight envelope)
    edges = np.concatenate([[0], np.cumsum(S)])
    for i in range(len(S)):
        seg = M[edges[i]:edges[i + 1]]
        assert np.isclose(seg.max(), P[i], rtol=1e-12)


def _check_jax_matches_reference(M, k):
    S_ref, P_ref = get_segments_ref(M, k)
    T = 1 << max((len(M) - 1).bit_length(), 4)
    pad = np.zeros(T, np.float32)
    pad[: len(M)] = M
    S, P, n = get_segments(jnp.asarray(pad), jnp.int32(len(M)), k)
    n = int(n)
    assert n == len(S_ref)
    np.testing.assert_array_equal(np.asarray(S)[:n], S_ref)
    np.testing.assert_allclose(np.asarray(P)[:n], P_ref, rtol=1e-5)
    # padding slots zeroed
    assert np.all(np.asarray(S)[n:] == 0)


def _random_traces(num):
    rng = np.random.default_rng(1234)
    for _ in range(num):
        L = int(rng.integers(1, 200))
        M = rng.uniform(0.0078125, 100.0, L).astype(np.float32)
        if rng.random() < 0.3:  # plateau-heavy traces stress the merge rule
            M = np.round(M / 20.0) * 20.0 + 0.01
        yield M, int(rng.integers(1, 10))


if HAVE_HYPOTHESIS:
    traces = st.lists(
        st.floats(min_value=0.0078125, max_value=100.0, allow_nan=False,
                  allow_infinity=False, width=32),
        min_size=1, max_size=200,
    ).map(np.asarray)

    @given(M=traces, k=st.integers(1, 10))
    @settings(max_examples=200, deadline=None)
    def test_envelope_properties(M, k):
        _check_envelope_properties(M, k)

    @given(M=traces, k=st.integers(1, 8))
    @settings(max_examples=100, deadline=None)
    def test_jax_matches_reference(M, k):
        _check_jax_matches_reference(M, k)
else:
    def test_envelope_properties():
        for M, k in _random_traces(200):
            _check_envelope_properties(M, k)

    def test_jax_matches_reference():
        for M, k in _random_traces(60):
            _check_jax_matches_reference(M, min(k, 8))


def test_bwa_like_example():
    """Fig. 1b / Fig. 2: long flat phase then a step is segmented exactly."""
    M = np.concatenate([np.full(80, 5.1), np.full(20, 10.7)])
    S, P = get_segments_ref(M, 2)
    assert list(S) == [80, 20]
    np.testing.assert_allclose(P, [5.1, 10.7])


def test_merge_error_greedy():
    """Merging always removes the smallest (P_{i+1}-P_i)*S_i pair first."""
    M = np.asarray([1.0, 1.0, 1.0, 2.0, 10.0])  # e0 = 1*3, e1 = 8*1
    S, P = get_segments_ref(M, 2)
    # cheaper to merge the (1.0 x3) segment into the 2.0 one
    assert list(S) == [4, 1]
    np.testing.assert_allclose(P, [2.0, 10.0])


def test_monotone_input_single_segment_when_k1():
    M = np.linspace(1, 5, 50)
    S, P = get_segments_ref(M, 1)
    assert list(S) == [50]
    np.testing.assert_allclose(P, [5.0])


def test_invalid_inputs():
    with pytest.raises(ValueError):
        get_segments_ref(np.zeros((2, 2)), 2)
    with pytest.raises(ValueError):
        get_segments_ref(np.asarray([1.0]), 0)
