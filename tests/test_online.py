"""Online predictor lifecycle: observe/refit, online replay, tovar-feedback.

The load-bearing checks:

* online replay with ``refit="never"`` must reproduce the offline
  :class:`ExperimentResult` **bitwise** on the fleet engine (same per-lane
  arithmetic, same reduction order — see ``fleet.subset_batch``),
* :class:`TovarFeedback`'s carried peak-distribution state must match a
  from-scratch oracle refit on the concatenated history,
* feedback must pay: ``tovar-feedback`` under ``refit="on_failure"``
  strictly reduces total wastage vs the fit-once ``tovar-ppm`` on a seeded
  workflow replay.
"""

import numpy as np
import pytest

from repro.core import (
    ExecutionOutcome,
    KSPlus,
    RefitPolicy,
    TovarFeedback,
    TovarPPM,
)
from repro.sched import evaluate_workflow
from repro.traces import eager, sarek


def _traces(n=10, seed=0, lo=2.0, hi=6.0):
    rng = np.random.default_rng(seed)
    mems, dts, Is = [], [], []
    for _ in range(n):
        I = float(rng.uniform(1, 5))
        L = int(20 + 6 * I)
        m = np.concatenate([np.full(int(0.6 * L), lo),
                            np.full(L - int(0.6 * L), hi + 0.3 * I)])
        mems.append(m)
        dts.append(1.0)
        Is.append(I)
    return mems, dts, Is


class TestRefitPolicy:
    def test_parse_forms(self):
        assert RefitPolicy.parse("never") == RefitPolicy("never")
        assert RefitPolicy.parse("on_failure") == RefitPolicy("on_failure")
        assert RefitPolicy.parse("every_n") == RefitPolicy("every_n", 1)
        assert RefitPolicy.parse("every_5") == RefitPolicy("every_n", 5)
        p = RefitPolicy("every_n", 3)
        assert RefitPolicy.parse(p) is p
        with pytest.raises(ValueError):
            RefitPolicy.parse("sometimes")
        with pytest.raises(ValueError):
            RefitPolicy("every_n", 0)

    def test_due(self):
        assert not RefitPolicy("never").due(10, 10)
        assert RefitPolicy("every_n", 3).due(3, 0)
        assert not RefitPolicy("every_n", 3).due(2, 0)
        assert RefitPolicy("on_failure").due(1, 1)
        assert not RefitPolicy("on_failure").due(5, 0)
        assert not RefitPolicy("on_failure").due(0, 0)


class TestLifecycle:
    def test_outcome_defaults(self):
        o = ExecutionOutcome(mem=np.asarray([1.0, 3.0]), dt=2.0, input_gb=1.0)
        assert o.peak == 3.0 and o.runtime == 4.0
        assert o.succeeded and not o.oomed
        assert ExecutionOutcome(mem=o.mem, dt=1.0, input_gb=1.0,
                                retries=2).oomed
        assert ExecutionOutcome(mem=o.mem, dt=1.0, input_gb=1.0,
                                succeeded=False).oomed
        assert ExecutionOutcome(mem=o.mem, dt=1.0, input_gb=1.0,
                                peak_used=9.0).peak == 9.0

    def test_observe_refit_cycle(self):
        mems, dts, Is = _traces()
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        plan0 = m.predict(3.0)
        m.observe(ExecutionOutcome(mem=np.full(40, 20.0), dt=1.0,
                                   input_gb=3.0))
        assert not m.refit("never")           # policy says no
        np.testing.assert_array_equal(m.predict(3.0).peaks, plan0.peaks)
        assert m.refit("every_n")             # consumes the observation
        assert m.predict(3.0).peaks[-1] > plan0.peaks[-1]
        assert not m.refit("every_n")         # nothing pending anymore

    def test_on_failure_requires_oom(self):
        mems, dts, Is = _traces()
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        m.observe(ExecutionOutcome(mem=mems[0], dt=1.0, input_gb=Is[0]))
        assert not m.refit("on_failure")
        m.observe(ExecutionOutcome(mem=mems[0], dt=1.0, input_gb=Is[0],
                                   retries=1))
        assert m.refit("on_failure")

    def test_fit_resets_history(self):
        mems, dts, Is = _traces()
        m = KSPlus(k=2)
        m.fit(mems, dts, Is)
        m.observe(ExecutionOutcome(mem=mems[0], dt=1.0, input_gb=Is[0],
                                   retries=1))
        m.fit(mems, dts, Is)  # re-seeding clears pending/failures
        assert not m.refit("on_failure")


class TestTovarFeedbackState:
    def test_state_carryover_vs_from_scratch_oracle(self):
        """Incremental (peak, runtime) state == a fresh fit on the
        concatenated history, outcome for outcome."""
        mems, dts, Is = _traces(n=8, seed=2)
        extra, edts, eIs = _traces(n=6, seed=3, hi=11.0)
        online = TovarFeedback(machine_memory=64.0)
        online.fit(mems, dts, Is)
        for i, (m, d, I) in enumerate(zip(extra, edts, eIs)):
            online.observe(ExecutionOutcome(mem=m, dt=d, input_gb=I,
                                            retries=1))
            assert online.refit("on_failure")
            oracle = TovarFeedback(machine_memory=64.0)
            oracle.fit(mems + extra[: i + 1], dts + edts[: i + 1],
                       Is + eIs[: i + 1])
            assert online._first_alloc == oracle._first_alloc
            np.testing.assert_array_equal(np.sort(online._peaks),
                                          np.sort(oracle._peaks))

    def test_no_traces_retained(self):
        """Online state is O(#executions): summary only, no trace copies."""
        mems, dts, Is = _traces(n=4)
        m = TovarFeedback()
        m.fit(mems, dts, Is)
        m.observe(ExecutionOutcome(mem=mems[0], dt=1.0, input_gb=Is[0]))
        assert all(t is None for t in m._life.mems)
        assert len(m._peaks) == 5

    def test_offline_matches_tovar_ppm(self):
        """Fit-once TovarFeedback is exactly TovarPPM (same solve)."""
        mems, dts, Is = _traces(n=12, seed=5)
        a = TovarPPM(machine_memory=32.0)
        b = TovarFeedback(machine_memory=32.0)
        a.fit(mems, dts, Is)
        b.fit(mems, dts, Is)
        assert a._first_alloc == b._first_alloc


@pytest.mark.parametrize("wff,n", [(eager, 10), (sarek, 8)])
def test_online_never_matches_offline_bitwise(wff, n):
    """mode='online', refit='never' reproduces the offline ExperimentResult
    bitwise on the fleet engine — every method, every family."""
    wf = wff(n)
    off = evaluate_workflow(wf, seed=0, train_frac=0.5, k=3)
    on = evaluate_workflow(wf, seed=0, train_frac=0.5, k=3,
                           mode="online", refit="never")
    assert set(off.methods) == set(on.methods)
    for mname, a in off.methods.items():
        b = on.methods[mname]
        assert a.total_gbs == b.total_gbs, mname
        assert a.retries == b.retries, mname
        assert a.failures == b.failures, mname
        assert a.per_family_gbs == b.per_family_gbs, mname


def test_online_round_size_invariant_under_never():
    """With refit='never' the round partitioning cannot change results."""
    wf = eager(8)
    r1 = evaluate_workflow(wf, seed=1, train_frac=0.5, k=3,
                           methods=["ks+", "witt-p95"],
                           mode="online", refit="never", round_size=1)
    r3 = evaluate_workflow(wf, seed=1, train_frac=0.5, k=3,
                           methods=["ks+", "witt-p95"],
                           mode="online", refit="never", round_size=3)
    for m in r1.methods:
        assert r1.methods[m].total_gbs == r3.methods[m].total_gbs


def test_online_mode_validation():
    wf = eager(6)
    with pytest.raises(ValueError):
        evaluate_workflow(wf, seed=0, train_frac=0.5, mode="online",
                          engine="oracle")
    with pytest.raises(ValueError):
        evaluate_workflow(wf, seed=0, train_frac=0.5, mode="sideways")
    with pytest.raises(ValueError):
        evaluate_workflow(wf, seed=0, train_frac=0.5, mode="online",
                          round_size=0)


def test_tovar_feedback_beats_tovar_ppm_online():
    """The acceptance bar: feedback strictly reduces total wastage vs the
    fit-once baseline on a seeded workflow replay (and costs fewer
    retries, because refits stop repeat OOMs on under-sampled families)."""
    res = evaluate_workflow(eager(10), seed=0, train_frac=0.25, k=4,
                            methods=["tovar-ppm", "tovar-feedback"],
                            mode="online", refit="on_failure")
    ppm = res.methods["tovar-ppm"]
    fb = res.methods["tovar-feedback"]
    assert fb.total_gbs < ppm.total_gbs
    assert fb.retries < ppm.retries


def test_frozen_baseline_stays_frozen_online():
    """tovar-ppm (spec online=False) must replay identically whatever the
    refit policy — the paper baseline cannot silently learn."""
    never = evaluate_workflow(eager(8), seed=2, train_frac=0.5,
                              methods=["tovar-ppm"], mode="online",
                              refit="never")
    onf = evaluate_workflow(eager(8), seed=2, train_frac=0.5,
                            methods=["tovar-ppm"], mode="online",
                            refit="on_failure")
    assert never.methods["tovar-ppm"].total_gbs == \
        onf.methods["tovar-ppm"].total_gbs
