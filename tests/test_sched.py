"""Scheduler layer: paper-experiment simulator, cluster sim, elastic."""

import numpy as np
import pytest

from repro.core import AllocationPlan, KSPlus, ksplus_retry
from repro.sched import (
    ClusterSim,
    ElasticPlanner,
    Job,
    Node,
    evaluate_workflow,
)
from repro.traces import eager, sarek


@pytest.fixture(scope="module")
def eager_result():
    return evaluate_workflow(eager(20), seed=0, train_frac=0.5, k=4)


class TestPaperExperiment:
    def test_ksplus_beats_peak_predictors(self, eager_result):
        res = eager_result
        assert res.methods["ks+"].total_gbs < res.methods["ppm-improved"].total_gbs
        assert res.methods["ks+"].total_gbs < res.methods["tovar-ppm"].total_gbs
        assert res.methods["ks+"].total_gbs < res.methods["witt-p95"].total_gbs
        assert res.methods["ks+"].total_gbs < res.methods["default"].total_gbs

    def test_ksplus_beats_ksegments(self, eager_result):
        res = eager_result
        assert res.methods["ks+"].total_gbs < \
            res.methods["k-segments-selective"].total_gbs

    def test_no_unsatisfiable_tasks(self, eager_result):
        for mr in eager_result.methods.values():
            assert mr.failures == 0, mr.name

    def test_per_family_breakdown_sums(self, eager_result):
        for mr in eager_result.methods.values():
            assert np.isclose(sum(mr.per_family_gbs.values()), mr.total_gbs)

    def test_sarek_runs(self):
        res = evaluate_workflow(sarek(10), seed=1, train_frac=0.5, k=4,
                                methods=["ks+", "ppm-improved"])
        assert res.methods["ks+"].total_gbs < \
            res.methods["ppm-improved"].total_gbs


class TestClusterSim:
    def _jobs(self, n, rng, plan_scale=1.1):
        jobs = []
        for j in range(n):
            L = int(rng.integers(20, 60))
            mem = np.abs(rng.normal(4, 0.5, L))
            peak = mem.max()
            plan = AllocationPlan(starts=np.zeros(1),
                                  peaks=np.asarray([peak * plan_scale]))
            jobs.append(Job(jid=j, family="t", input_gb=1.0, mem=mem,
                            dt=1.0, plan=plan, est_runtime=float(L)))
        return jobs

    def test_all_jobs_finish(self):
        rng = np.random.default_rng(0)
        sim = ClusterSim([Node(0, 64.0), Node(1, 64.0)])
        jobs = self._jobs(12, rng)
        res = sim.run(jobs, ksplus_retry)
        assert res.unschedulable == 0
        assert res.makespan > 0
        assert res.avg_utilization > 0

    def test_oom_triggers_retry(self):
        rng = np.random.default_rng(1)
        sim = ClusterSim([Node(0, 64.0)])
        jobs = self._jobs(4, rng, plan_scale=0.7)  # under-allocated
        res = sim.run(jobs, lambda p, t, u: p.with_(
            peaks=np.maximum(p.peaks * 2, u * 1.1)))
        assert res.retries > 0
        assert res.unschedulable == 0

    def test_tight_envelopes_increase_packing(self):
        """KS+-style tight envelopes finish the same jobs sooner than
        peak-sized allocations on a memory-constrained node: staggered
        high-memory phases co-schedule under the time-varying residual."""
        def jobs_with(env_kind):
            jobs = []
            for j in range(8):
                L = 24 + 6 * j  # heterogeneous runtimes stagger the phases
                split = int(0.7 * L)
                mem = np.concatenate([np.full(split, 2.0),
                                      np.full(L - split, 8.0)])
                if env_kind == "tight":
                    plan = AllocationPlan(
                        starts=np.asarray([0.0, split - 2.0]),
                        peaks=np.asarray([2.3, 9.0]))
                else:
                    plan = AllocationPlan(starts=np.zeros(1),
                                          peaks=np.asarray([9.0]))
                jobs.append(Job(jid=j, family="t", input_gb=1.0, mem=mem,
                                dt=1.0, plan=plan, est_runtime=float(L)))
            return jobs
        node_cap = 22.0
        res_tight = ClusterSim([Node(0, node_cap)]).run(
            jobs_with("tight"), ksplus_retry)
        res_peak = ClusterSim([Node(0, node_cap)]).run(
            jobs_with("peak"), ksplus_retry)
        assert res_tight.makespan < res_peak.makespan
        assert res_tight.total_wastage_gbs < res_peak.total_wastage_gbs
        assert res_tight.retries == 0 and res_tight.unschedulable == 0


class TestElastic:
    def test_admission_and_churn(self):
        pl_ = ElasticPlanner()
        pl_.node_join("n0", 32.0)
        pl_.node_join("n1", 32.0)
        env = AllocationPlan(starts=np.zeros(1), peaks=np.asarray([10.0]))
        placed = [pl_.admit(f"j{i}", env, now=0.0) for i in range(6)]
        assert all(p is not None for p in placed)
        assert pl_.admit("j-over", AllocationPlan(
            starts=np.zeros(1), peaks=np.asarray([40.0])), 0.0) is None
        evicted = pl_.node_leave("n0")
        assert len(evicted) > 0  # those jobs must checkpoint + requeue


class TestHBMFootprint:
    def test_envelope_prediction(self):
        from repro.sched import HBMFootprintModel
        m = HBMFootprintModel(k=2)
        for toks in (1000, 2000, 4000, 8000):
            env = np.concatenate([
                np.full(10, 1.0 + toks / 4000),
                np.full(10, 2.0 + toks / 2000)])
            m.observe(toks, env)
        m.fit()
        plan = m.predict(6000)
        assert plan.peaks[-1] > plan.peaks[0]
        assert plan.peaks[-1] >= 2.0 + 6000 / 2000  # covers w/ offset
