"""Distributed-MoE equivalence: shard_map dispatch == global reference.

Runs in a subprocess with 8 forced host devices so the main pytest process
keeps its single-device view.
"""

import os
import subprocess
import sys

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.partitioning import auto_axis_types, mesh_context, default_rules
from repro.models.moe import moe_block, moe_block_local

mesh = jax.make_mesh((4, 2), ("data", "model"), **auto_axis_types(2))
rng = np.random.default_rng(7)
d, E, ff = 32, 8, 64
x = np.asarray(rng.standard_normal((8, 16, d)), np.float32)
router = jnp.asarray(rng.standard_normal((d, E)) * 0.1, jnp.float32)
wg = jnp.asarray(rng.standard_normal((E, d, ff)) * 0.05, jnp.float32)
wu = jnp.asarray(rng.standard_normal((E, d, ff)) * 0.05, jnp.float32)
wd = jnp.asarray(rng.standard_normal((E, ff, d)) * 0.05, jnp.float32)

y_ref, _ = moe_block(jnp.asarray(x), router, wg, wu, wd,
                     topk=2, capacity_factor=4.0)
with mesh_context(mesh, default_rules(mesh)):
    xg = jax.device_put(jnp.asarray(x),
                        NamedSharding(mesh, P("data", None, None)))
    y_sm, aux = jax.jit(lambda xx: moe_block_local(
        xx, router, wg, wu, wd, topk=2, capacity_factor=4.0))(xg)
    gfn = jax.jit(jax.grad(lambda xx: moe_block_local(
        xx, router, wg, wu, wd, topk=2, capacity_factor=4.0)[0].sum()))
    g = gfn(xg)
diff = float(jnp.max(jnp.abs(y_ref - y_sm)))
assert diff < 1e-5, diff
assert bool(jnp.isfinite(g).all())
print("SHARDMAP-MOE-OK", diff)
"""


def test_shardmap_moe_matches_reference():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _CODE], cwd=os.getcwd(),
                       env=env, capture_output=True, text=True, timeout=540)
    assert "SHARDMAP-MOE-OK" in r.stdout, r.stdout + r.stderr
