"""repro.obs: tracer, metrics registry, exporters, and the
zero-perturbation contract.

The tracing contract under test: with observability off, instrumented
hot paths record *nothing* (one module-attribute check); with it on,
spans/instants/dispatch tags land in the bounded ring and metrics in the
global registry — and a traced replay stays **bitwise identical** to an
untraced one on every decision log (placements, retries, evictions) and
on served plans.  Exporters must round-trip: Chrome-trace JSON and JSONL
both reload through ``read_events``, ``summarize`` reports every span
name, and the Prometheus text form is well-shaped.
"""

import json
import os
import threading

import numpy as np
import pytest

from repro import obs
from repro.analysis import contracts
from repro.core import AllocationPlan, RetrySpec
from repro.obs.__main__ import main as obs_cli
from repro.sched import ClusterSim, FaultSchedule, Job, Node


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends disabled with an empty default-size
    ring and an empty registry (``enable(ring=N)`` resizes the module
    ring, so tests that shrink it must not leak that into the next)."""

    def reset():
        obs.disable()
        if obs.trace._ring.maxlen != obs.trace.DEFAULT_RING:
            obs.trace._ring = type(obs.trace._ring)(
                maxlen=obs.trace.DEFAULT_RING)
        obs.clear()
        obs.REGISTRY.clear()

    reset()
    yield
    reset()


# -------------------------------------------------------------------- tracer
class TestTracer:
    def test_disabled_records_nothing(self):
        with obs.span("a", x=1) as sp:
            sp.add(y=2)
        obs.instant("b")
        contracts.record_dispatch("some.tag")
        assert obs.events() == []

    def test_span_event_shape(self):
        with obs.tracing():
            with obs.span("admission.drain", q=3) as sp:
                sp.add(placed=2)
        (ev,) = obs.events()
        assert ev["ph"] == "X" and ev["name"] == "admission.drain"
        assert ev["dur"] >= 0.0 and ev["ts"] >= 0.0
        assert ev["args"] == {"q": 3, "placed": 2}
        assert ev["tid"] == threading.get_ident()

    def test_nesting_orders_inner_first(self):
        with obs.tracing():
            with obs.span("outer"):
                with obs.span("inner"):
                    pass
        names = [e["name"] for e in obs.events()]
        assert names == ["inner", "outer"]

    def test_thread_local_stacks(self):
        """Concurrent spans on two threads never cross-attribute."""
        with obs.tracing():
            barrier = threading.Barrier(2)

            def worker(name):
                with obs.span(name):
                    barrier.wait(timeout=5)
                    contracts.record_dispatch(f"tag.{name}")
                    barrier.wait(timeout=5)

            t = threading.Thread(target=worker, args=("t1",))
            t.start()
            worker("t0")
            t.join()
        by_name = {e["name"]: e for e in obs.events()}
        assert by_name["t0"]["dispatches"] == {"tag.t0": 1}
        assert by_name["t1"]["dispatches"] == {"tag.t1": 1}
        assert by_name["t0"]["tid"] != by_name["t1"]["tid"]

    def test_ring_is_bounded(self):
        with obs.tracing(ring=16):
            for i in range(100):
                obs.instant("e", i=i)
        evs = obs.events()
        assert len(evs) == 16
        assert evs[-1]["args"] == {"i": 99}  # newest survive

    def test_tracing_restores_prior_state(self):
        with obs.tracing():
            with obs.tracing():
                assert obs.trace.enabled
            assert obs.trace.enabled  # inner exit keeps outer's on
        assert not obs.trace.enabled

    def test_dispatch_attributed_to_open_span(self):
        with obs.tracing():
            with obs.span("work"):
                contracts.record_dispatch("fused.drain", 2)
                contracts.record_dispatch("fused.drain")
        (ev,) = obs.events()
        assert ev["dispatches"] == {"fused.drain": 3}

    def test_dispatch_without_span_is_loose_instant(self):
        with obs.tracing():
            contracts.record_dispatch("fused.drain")
        (ev,) = obs.events()
        assert ev["ph"] == "i" and ev["name"] == "dispatch:fused.drain"

    def test_disable_removes_dispatch_hook(self):
        with obs.tracing():
            assert contracts._obs_dispatch_hook is not None
        assert contracts._obs_dispatch_hook is None
        contracts.record_dispatch("late.tag")
        assert obs.events() == []


# ------------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_labels(self):
        c = obs.counter("serve.requests")
        c.inc(kind="predict")
        c.inc(2, kind="predict")
        c.inc(kind="evaluate")
        assert c.value(kind="predict") == 3
        assert c.value(kind="evaluate") == 1
        assert c.value(kind="absent") == 0

    def test_gauge_last_write_wins(self):
        g = obs.gauge("serve.queue_depth")
        g.set(5)
        g.set(2)
        assert g.value() == 2.0

    def test_histogram_buckets_cumulative(self):
        h = obs.hist("lat", buckets=(1.0, 10.0, 100.0))
        for v in (0.5, 0.7, 5.0, 1000.0):
            h.observe(v)
        assert h.count() == 4
        (row,) = h.snapshot()["values"]
        assert row["cumulative"] == [2, 3, 3, 4]  # last == count
        assert row["sum"] == pytest.approx(1006.2)

    def test_histogram_rejects_infinite_buckets(self):
        with pytest.raises(ValueError):
            obs.REGISTRY.hist("bad", buckets=(1.0, float("inf")))

    def test_series_bounded_sim_time(self):
        s = obs.REGISTRY.series("curve", maxlen=4)
        for t in range(10):
            s.append(float(t), t * 2.0)
        assert s.points() == [(6.0, 12.0), (7.0, 14.0),
                              (8.0, 16.0), (9.0, 18.0)]

    def test_registry_kind_conflict_is_loud(self):
        obs.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            obs.gauge("x")

    def test_get_or_create_returns_same_object(self):
        assert obs.counter("c") is obs.counter("c")


# ------------------------------------------------------------------- export
def _sample_ring():
    with obs.tracing():
        with obs.span("cluster.run", jobs=3) as sp:
            contracts.record_dispatch("admission.scatter", 2)
            sp.add(retries=1)
        obs.instant("cluster.event_batch", t=1.5, n=4)


class TestExport:
    def test_chrome_trace_round_trip(self, tmp_path):
        _sample_ring()
        path = tmp_path / "trace.perfetto.json"
        n = obs.write_chrome_trace(str(path))
        doc = json.loads(path.read_text())
        assert doc["displayTimeUnit"] == "ms"
        assert len(doc["traceEvents"]) == n == 2
        assert all(ev["pid"] == os.getpid() for ev in doc["traceEvents"])
        back = obs.read_events(str(path))
        assert len(back) == 2
        assert back[0]["dispatches"] == {"admission.scatter": 2}

    def test_jsonl_round_trip(self, tmp_path):
        _sample_ring()
        path = tmp_path / "trace.jsonl"
        n = obs.write_jsonl(str(path))
        back = obs.read_events(str(path))
        assert len(back) == n == 2
        assert [e["name"] for e in back] == [e["name"] for e in obs.events()]

    def test_summarize_reports_span_table(self):
        _sample_ring()
        text = obs.summarize()
        assert "cluster.run" in text
        assert "cluster.event_batch" in text  # loose instants section

    def test_summarize_cli(self, tmp_path, capsys):
        _sample_ring()
        path = tmp_path / "t.jsonl"
        obs.write_jsonl(str(path))
        assert obs_cli(["summarize", str(path)]) == 0
        assert "cluster.run" in capsys.readouterr().out

    def test_prometheus_text_shape(self):
        obs.counter("serve.requests").inc(3, kind="predict")
        obs.gauge("serve.queue_depth").set(7)
        h = obs.hist("serve.wait_s", buckets=(0.001, 0.01))
        h.observe(0.005)
        text = obs.prometheus_text()
        lines = text.splitlines()
        assert 'serve_requests{kind="predict"} 3' in lines
        assert "serve_queue_depth 7" in lines
        assert "# TYPE serve_wait_s histogram" in lines
        assert 'serve_wait_s_bucket{le="+Inf"} 1' in lines
        assert "serve_wait_s_count 1" in lines
        # dotted metric names sanitized for the exposition format
        assert "serve.requests" not in text

    def test_metrics_snapshot_json(self, tmp_path):
        obs.counter("c").inc()
        obs.REGISTRY.series("s").append(0.0, 1.0)
        path = tmp_path / "m.json"
        obs.write_metrics_snapshot(str(path))
        snap = json.loads(path.read_text())
        assert snap["c"]["kind"] == "counter"
        assert snap["s"]["points"] == [[0.0, 1.0]]


# ------------------------------------------------- zero-perturbation contract
def _nodes():
    return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0)]


def _workload(n_jobs=30, seed=0):
    rng = np.random.default_rng(seed)
    jobs = []
    for j in range(n_jobs):
        L = int(rng.integers(24, 60))
        split = int(rng.uniform(0.4, 0.8) * L)
        lo = float(rng.uniform(1.5, 3.0))
        hi = float(rng.uniform(5.0, 11.0))
        mem = np.concatenate([np.full(split, lo), np.full(L - split, hi)])
        under = rng.uniform() < 0.25
        plan = AllocationPlan(
            starts=np.asarray([0.0, max(split - 2.0, 1.0)]),
            peaks=np.asarray([lo * 1.15, hi * (0.9 if under else 1.12)]))
        jobs.append(Job(jid=j, family="t", input_gb=1.0, mem=mem, dt=1.0,
                        plan=plan, est_runtime=float(L)))
    return jobs


class TestZeroPerturbation:
    def test_traced_replay_bitwise_under_churn(self):
        churn = FaultSchedule.node_churn(_nodes(), rate=1.0 / 120.0,
                                         horizon=600.0, seed=0,
                                         mean_down=60.0)
        base = ClusterSim(_nodes(), engine="fused").run(
            _workload(), RetrySpec("ksplus"), faults=churn)
        assert obs.events() == []  # untraced run records nothing
        traced = ClusterSim(_nodes(), engine="fused").run(
            _workload(), RetrySpec("ksplus"), faults=churn, trace=True)
        assert traced.placements == base.placements
        assert traced.retries == base.retries
        assert traced.evictions == base.evictions
        assert traced.total_wastage_gbs == base.total_wastage_gbs
        assert not obs.trace.enabled  # trace=True is scoped to the run
        names = {e["name"] for e in obs.events()}
        assert "cluster.run" in names and "admission.drain" in names
        # The engine series landed, keyed by sim time.
        assert len(obs.REGISTRY.series("cluster.utilization")) > 0

    def test_traced_run_inside_enabled_scope_not_double_disabled(self):
        jobs = _workload(n_jobs=8)
        with obs.tracing():
            ClusterSim(_nodes(), engine="fused").run(
                jobs, RetrySpec("ksplus"), trace=True)
            assert obs.trace.enabled  # outer scope's switch survives

    def test_traced_serve_plans_bitwise(self):
        from repro.serve.bench import _run_tape, build_server, request_tape

        tape = request_tape(64, tenants=2, seed=3, repeat_pool=16)

        def plans(traced):
            clock = [0.0]
            srv = build_server(tenants=2, clock=lambda: clock[0])
            if traced:
                with obs.tracing():
                    return _run_tape(srv, tape)
            return _run_tape(srv, tape)

        base, traced = plans(False), plans(True)
        assert len(base) == len(traced) == 64
        for a, b in zip(base, traced):
            np.testing.assert_array_equal(a.starts, b.starts)
            np.testing.assert_array_equal(a.peaks, b.peaks)
        assert obs.counter("serve.requests").value(
            kind="predict", cache="miss") > 0
