"""repro.serve: batcher invariants, tenant state, caches, dispatch parity.

The serving contract under test: batched responses are **bitwise equal**
to per-request dispatch across mixed-tenant mixed-family traffic, tenant
refits fork copy-on-write snapshots without perturbing other tenants,
and caches invalidate exactly at refit scope.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import registry
from repro.core.fleet import packed_predict, simulate_fleet_many
from repro.core.predictor import ExecutionOutcome
from repro.core.registry import MissingCapabilityError
from repro.serve import (
    Backpressure,
    MicroBatcher,
    PredictionCache,
    PredictionServer,
    ProgramCache,
    ServeRequest,
    ServerClosed,
    TenantRegistry,
    UnknownFamilyError,
    UnknownTenantError,
)
from repro.serve.bench import FAMILIES, build_server, request_tape, synth_family


def _req(payload, t=0.0, family="f", tenant="ten", kind="predict"):
    return ServeRequest(kind=kind, tenant=tenant, family=family,
                        payload=payload, arrival=t)


def _recording_batcher(**kw):
    calls = []

    def dispatch(key, reqs):
        calls.append((key, list(reqs)))
        for r in reqs:
            r.future.set_result(r.payload)

    return MicroBatcher(dispatch, key_fn=lambda r: r.family, **kw), calls


# ------------------------------------------------------------ the batcher
class TestMicroBatcher:
    def test_deadline_flush_with_single_queued_request(self):
        now = [0.0]
        bat, calls = _recording_batcher(max_wait_s=0.002,
                                        clock=lambda: now[0])
        fut = bat.submit(_req("only", t=0.0))
        assert bat.pump(0.0015) == 0 and not fut.done  # deadline not due
        assert bat.pump(0.002) == 1                    # due: flush of one
        assert fut.done and fut.result(0) == "only"
        assert len(calls) == 1 and len(calls[0][1]) == 1
        assert bat.stats["deadline_flushes"] == 1

    def test_full_queue_flushes_inline(self):
        bat, calls = _recording_batcher(max_batch=4, max_wait_s=10.0)
        futs = [bat.submit(_req(i)) for i in range(4)]
        assert all(f.done for f in futs)  # saturation flush, no pump
        assert bat.stats["full_flushes"] == 1 and bat.depth == 0
        assert len(calls) == 1

    def test_buckets_group_by_key_and_keep_fifo(self):
        bat, calls = _recording_batcher(max_wait_s=10.0)
        for i, fam in enumerate("abab"):
            bat.submit(_req(i, family=fam))
        assert bat.flush() == 4
        assert len(calls) == 2  # one dispatch per bucket
        by_key = {key: [r.payload for r in reqs] for key, reqs in calls}
        assert by_key == {"a": [0, 2], "b": [1, 3]}

    def test_backpressure_rejects_at_max_queue(self):
        bat, _ = _recording_batcher(max_batch=2, max_queue=2,
                                    max_wait_s=10.0)
        bat._queue = [_req(0), _req(1)]  # saturate without flushing
        with pytest.raises(Backpressure):
            bat.submit(_req(2))
        assert bat.stats["rejected"] == 1

    def test_dispatch_error_scatters_to_futures(self):
        def boom(key, reqs):
            raise RuntimeError("bucket exploded")

        bat = MicroBatcher(boom, key_fn=lambda r: r.family, max_wait_s=10.0)
        f1, f2 = bat.submit(_req(1)), bat.submit(_req(2))
        assert bat.flush() == 2
        for f in (f1, f2):
            with pytest.raises(RuntimeError, match="bucket exploded"):
                f.result(0)

    def test_threaded_deadline_loop(self):
        bat, calls = _recording_batcher(max_wait_s=0.001)
        bat.start()
        try:
            fut = bat.submit(_req("bg", t=time.monotonic()))
            assert fut.result(timeout=2.0) == "bg"
        finally:
            bat.stop()
        assert bat._thread is None and len(calls) == 1

    def test_future_timeout(self):
        bat, _ = _recording_batcher(max_wait_s=10.0)
        fut = bat.submit(_req(0))
        with pytest.raises(TimeoutError):
            fut.result(timeout=0.01)


# ------------------------------------------------------------ tenant state
class TestTenantRegistry:
    def _seeded(self, n_tenants=2):
        reg = TenantRegistry()
        for i in range(n_tenants):
            reg.add_tenant(f"t{i}")
        mems, dts, inputs = synth_family(0)
        reg.seed("fam", "ks+", mems, dts, inputs)
        return reg

    def test_seed_shares_one_frozen_snapshot(self):
        reg = self._seeded()
        s0, s1 = reg.snapshot("t0", "fam"), reg.snapshot("t1", "fam")
        assert s0 is s1 and s0.version == 0
        assert s0.method_name == "ks+"

    def test_unknown_names_raise_loudly(self):
        reg = self._seeded()
        with pytest.raises(UnknownTenantError, match="ghost"):
            reg.snapshot("ghost", "fam")
        with pytest.raises(UnknownFamilyError, match="nope"):
            reg.snapshot("t0", "nope")
        with pytest.raises(ValueError, match="already exists"):
            reg.add_tenant("t0")

    def test_seed_requires_uniform_dt(self):
        reg = TenantRegistry()
        reg.add_tenant("t0")
        mems, dts, inputs = synth_family(0)
        dts = [1.0] * (len(dts) - 1) + [2.0]
        with pytest.raises(ValueError, match="uniform"):
            reg.seed("fam", "ks+", mems, dts, inputs)

    def test_refit_forks_only_the_refitting_tenant(self):
        reg = self._seeded()
        old = reg.snapshot("t0", "fam")
        events = []
        reg.on_refit(lambda *a: events.append(a))
        out = ExecutionOutcome(mem=np.full(40, 9.0), dt=1.0, input_gb=3.0,
                               succeeded=True)
        assert reg.observe("t0", "fam", out) == 1
        assert reg.refit("t0", "fam") is True
        new = reg.snapshot("t0", "fam")
        assert new is not old and new.version == 1 and new.sid != old.sid
        assert len(new.train_mems) == len(old.train_mems) + 1
        assert reg.snapshot("t1", "fam") is old  # other tenant untouched
        assert events == [("t0", "fam", old, new)]
        # pending was consumed: the policy is no longer due
        assert reg.refit("t0", "fam") is False

    def test_refit_policy_not_due(self):
        reg = self._seeded()
        out = ExecutionOutcome(mem=np.full(40, 9.0), dt=1.0, input_gb=3.0,
                               succeeded=True)
        reg.observe("t0", "fam", out)
        assert reg.refit("t0", "fam", policy="every_5") is False
        assert reg.snapshot("t0", "fam").version == 0

    def test_refit_offline_method_raises_named_error(self):
        reg = TenantRegistry()
        reg.add_tenant("t0")
        mems, dts, inputs = synth_family(0)
        reg.seed("frozen", "tovar-ppm", mems, dts, inputs)
        reg.observe("t0", "frozen", ExecutionOutcome(
            mem=np.full(40, 9.0), dt=1.0, input_gb=3.0, succeeded=True))
        with pytest.raises(MissingCapabilityError, match="online"):
            reg.refit("t0", "frozen")


# ----------------------------------------------------------------- caches
class TestCaches:
    def test_prediction_cache_hits_evictions_invalidation(self):
        cache = PredictionCache(max_entries=2)
        assert cache.get(1, 2.0) is None
        cache.put(1, 2.0, "a")
        cache.put(1, 3.0, "b")
        assert cache.get(1, 2.0) == "a"
        cache.put(2, 2.0, "c")  # evicts the oldest (sid 1, 2.0)
        assert cache.get(1, 2.0) is None
        assert cache.stats.evictions == 1
        assert cache.invalidate_sid(1) == 1  # the surviving sid-1 entry
        assert cache.get(1, 3.0) is None
        assert cache.get(2, 2.0) == "c"

    def test_program_cache_shapes_and_trace_residency(self):
        prog = ProgramCache()
        assert prog.note_shape("ks+", "fam", 4, 1.0, (8, 4)) is False
        assert prog.note_shape("ks+", "fam", 4, 1.0, (8, 4)) is True
        assert prog.distinct_shapes == 1
        builds = []
        got1 = prog.trace_batch("t0", "fam", 7,
                                lambda: builds.append(1) or "batch")
        got2 = prog.trace_batch("t0", "fam", 7,
                                lambda: builds.append(1) or "other")
        assert got1 == got2 == "batch" and builds == [1]
        assert prog.invalidate_tenant_family("t0", "fam") == 1
        prog.trace_batch("t0", "fam", 8, lambda: builds.append(1) or "b2")
        assert len(builds) == 2


# --------------------------------------------------------- serve dispatch
def _mixed_server(batching, *, tenants=6, seed=0):
    srv = PredictionServer(batching=batching, cache_predictions=False,
                           max_batch=64, max_wait_s=10.0)
    for i in range(tenants):
        srv.add_tenant(f"tenant{i}")
    for j, (family, method) in enumerate(FAMILIES):
        mems, dts, inputs = synth_family(seed + j)
        srv.seed_family(family, method, mems, dts, inputs)
    mems, dts, inputs = synth_family(seed + len(FAMILIES))
    srv.seed_family("kseg", "k-segments-selective", mems, dts, inputs)
    return srv


class TestServeDispatch:
    def test_batched_bitwise_equals_sequential_mixed_traffic(self):
        """The precision contract over every method family at once."""
        tape = request_tape(96, 6, seed=11)
        tape += [(t, "kseg", x) for t, _, x in request_tape(24, 6, seed=12)]
        batched = _mixed_server(batching=True)
        seq = _mixed_server(batching=False)
        futs = [batched.submit("predict", t, f, x) for t, f, x in tape]
        batched.drain()
        got = [f.result(0) for f in futs]
        for (tenant, family, x), plan in zip(tape, got):
            single = seq.client(tenant).predict(family, x)
            assert np.array_equal(plan.starts, single.starts)
            assert np.array_equal(plan.peaks, single.peaks)
        # several tenants + families coalesced into few bucket dispatches
        assert batched.stats()["batcher"]["flushes"] < len(tape) / 10

    def test_served_plans_match_direct_method_oracle(self):
        """Server output == the fitted method's own predict(), bitwise."""
        srv = _mixed_server(batching=True)
        oracles = {}
        for j, (family, method) in enumerate(
                tuple(FAMILIES) + (("kseg", "k-segments-selective"),)):
            mems, dts, inputs = synth_family(j)
            m = registry.make(method)
            m.fit(mems, dts, inputs)
            oracles[family] = m
        client = srv.client("tenant0")
        for family, m in oracles.items():
            for x in (1.25, 3.0, 4.75):
                plan = client.predict(family, x)
                want = m.predict(x)
                assert np.array_equal(plan.starts, want.starts), family
                assert np.array_equal(plan.peaks, want.peaks), family

    def test_prediction_cache_and_refit_invalidation(self):
        srv = build_server(tenants=2, batching=True, seed=0)
        c = srv.client("tenant0")
        a = c.predict("align", 2.5)
        b = c.predict("align", 2.5)
        assert b is a  # submit-time hit: the cached plan object
        assert srv.predictions.stats.hits == 1
        # tenant1 shares the seed snapshot -> shares the cache entry
        assert srv.client("tenant1").predict("align", 2.5) is a
        c.observe("align", ExecutionOutcome(
            mem=np.full(40, 9.0), dt=1.0, input_gb=2.5, succeeded=True))
        assert c.refit("align") is True
        after = c.predict("align", 2.5)
        assert after is not a  # refit-scoped invalidation
        assert srv.client("tenant1").predict("align", 2.5) is a  # unscathed

    def test_evaluate_matches_fleet_oracle(self):
        srv = build_server(tenants=1, batching=True, seed=0)
        res = srv.client("tenant0").evaluate("align")
        mems, dts, inputs = synth_family(0)
        m = registry.make("ks+")
        m.fit(mems, dts, inputs)
        want = simulate_fleet_many(
            [(packed_predict(m, inputs), m.retry_spec)], list(mems),
            dts[0], machine_memory=128.0)[0]
        assert res.total_gbs == float(want.total_gbs)
        assert res.n == len(mems)
        assert res.succeeded == int(want.succeeded.sum())

    def test_tune_offset_matches_registry_oracle(self):
        srv = build_server(tenants=1, batching=True, seed=0)
        got = srv.client("tenant0").tune_offset("align")
        mems, dts, inputs = synth_family(0)
        m = registry.make("ks+")
        m.fit(mems, dts, inputs)
        best, totals = registry.tune_offset(m, mems, dts, inputs,
                                            machine_memory=128.0)
        assert got.best == best
        assert np.array_equal(got.totals, totals)

    def test_seed_rejects_unpacked_method(self):
        class NoPacked:
            def fit(self, mems, dts, inputs):
                pass

        @registry.register_method("test-nopack", retry=None, cls=NoPacked,
                                  packed=False)
        def _make(ctx):
            return NoPacked()

        try:
            srv = PredictionServer()
            srv.add_tenant("t0")
            mems, dts, inputs = synth_family(0)
            with pytest.raises(MissingCapabilityError, match="packed"):
                srv.seed_family("fam", "test-nopack", mems, dts, inputs)
        finally:
            registry.unregister_method("test-nopack")

    def test_unknown_kind_rejected(self):
        srv = build_server(tenants=1, batching=False, seed=0)
        with pytest.raises(ValueError, match="unknown request kind"):
            srv.submit("frobnicate", "tenant0", "align", 1.0)

    def test_threaded_server_round_trip(self):
        srv = build_server(tenants=2, batching=True, seed=0,
                           max_wait_s=0.001)
        srv.start()
        try:
            plans = [srv.client("tenant0").predict("align", 1.0 + 0.1 * i)
                     for i in range(5)]
        finally:
            srv.stop()
        assert all(p.peaks.size > 0 for p in plans)

    def test_concurrent_clients_threaded(self):
        """Many client threads against the background flush loop."""
        srv = build_server(tenants=4, batching=True, seed=0,
                           max_wait_s=0.001)
        srv.start()
        errors = []

        def worker(i):
            try:
                c = srv.client(f"tenant{i % 4}")
                for j in range(20):
                    p = c.predict("align", 1.0 + (i * 20 + j) % 40 * 0.1)
                    assert p.peaks.size > 0
            except BaseException as exc:  # pragma: no cover - failure path
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        srv.stop()
        assert not errors


class TestShutdownAndLiveness:
    """``close()`` semantics and the refit-vs-predict liveness contract:
    shutdown never strands a blocked caller, and concurrent refits never
    leave the prediction/program caches pointing at dead snapshots."""

    def test_close_fails_queued_futures(self):
        bat = MicroBatcher(lambda key, reqs: None, lambda r: r.family,
                           max_wait_s=10.0)
        futs = [bat.submit(_req(float(i), t=0.0)) for i in range(3)]
        bat.close()
        for f in futs:
            assert f.done
            with pytest.raises(ServerClosed, match="still queued"):
                f.result(0)

    def test_submit_after_close_rejected(self):
        bat = MicroBatcher(lambda key, reqs: None, lambda r: r.family)
        bat.close()
        with pytest.raises(ServerClosed, match="rejected"):
            bat.submit(_req(1.0))

    def test_close_stops_pump_thread_and_is_idempotent(self):
        bat = MicroBatcher(lambda key, reqs: None, lambda r: r.family,
                           max_wait_s=10.0)
        bat.start()
        fut = bat.submit(_req(1.0, t=time.monotonic()))
        bat.close()
        assert bat._thread is None
        with pytest.raises(ServerClosed):
            fut.result(0)
        bat.close()  # second close is a no-op, not an error

    def test_stop_drains_close_abandons(self):
        served = []
        bat = MicroBatcher(
            lambda key, reqs: [r.future.set_result(served.append(r.payload))
                               for r in reqs],
            lambda r: r.family, max_wait_s=10.0)
        bat.start()
        bat.submit(_req(1.0, t=time.monotonic()))
        bat.stop()  # stop flushes what is pending
        assert served == [1.0]
        bat.submit(_req(2.0, t=time.monotonic()))
        bat.close()  # close drops it
        assert served == [1.0]

    def test_blocked_caller_unblocked_by_close(self):
        """A caller waiting in result() gets ServerClosed, not a hang."""
        bat = MicroBatcher(lambda key, reqs: None, lambda r: r.family,
                           max_wait_s=60.0)
        fut = bat.submit(_req(1.0, t=time.monotonic()))
        caught = []

        def waiter():
            try:
                fut.result(30.0)
            except BaseException as exc:
                caught.append(exc)

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.05)  # let the waiter block on the future
        bat.close()
        t.join(timeout=5.0)
        assert not t.is_alive()
        assert len(caught) == 1 and isinstance(caught[0], ServerClosed)

    def test_server_context_manager_closes(self):
        with build_server(tenants=1, seed=0) as srv:
            assert srv.client("tenant0").predict("align", 1.5).peaks.size
        with pytest.raises(ServerClosed):
            srv.submit("predict", "tenant0", "align", 9.9)

    def test_threaded_refit_vs_predict_liveness(self):
        """Tenant refits race reader predictions on the live thread; the
        copy-on-refit snapshots keep readers on their seed sid, and at
        quiescence every cached prediction belongs to a live snapshot."""
        srv = build_server(tenants=4, batching=True, seed=0,
                           max_wait_s=0.001)
        srv.start()
        errors: list = []
        stop_evt = threading.Event()
        seed_sid = srv.tenants.snapshot("tenant1", "align").sid

        def reader(i):
            try:
                c = srv.client(f"tenant{i}")
                while not stop_evt.is_set():
                    p = c.predict("align", 1.0 + 0.1 * (i % 8))
                    assert p.peaks.size > 0
            except BaseException as exc:  # pragma: no cover - failure path
                errors.append(exc)

        def writer():
            try:
                c = srv.client("tenant0")
                for j in range(8):
                    c.observe("align", ExecutionOutcome(
                        mem=np.full(40, 5.0 + j), dt=1.0, input_gb=2.0,
                        succeeded=True))
                    assert c.refit("align") is True
                    assert c.predict("align", 2.0).peaks.size > 0
            except BaseException as exc:  # pragma: no cover - failure path
                errors.append(exc)

        readers = [threading.Thread(target=reader, args=(i,))
                   for i in (1, 2, 3)]
        wt = threading.Thread(target=writer)
        for t in readers:
            t.start()
        wt.start()
        wt.join(timeout=30.0)
        stop_evt.set()
        for t in readers:
            t.join(timeout=30.0)
        srv.stop()
        assert not errors
        assert not wt.is_alive() and not any(t.is_alive() for t in readers)
        # Readers stayed on the seed snapshot; the writer moved off it.
        for i in (1, 2, 3):
            assert srv.tenants.snapshot(f"tenant{i}", "align").sid == seed_sid
        assert srv.tenants.snapshot("tenant0", "align").sid != seed_sid
        # Quiescent cache invariant: every cached sid is still served by
        # some tenant — refit invalidation left no dead-snapshot entries.
        with srv.predictions._lock:
            cached_sids = [sid for sid, keys in
                           srv.predictions._by_sid.items() if keys]
        assert cached_sids
        for sid in cached_sids:
            assert srv._sid_live(sid), f"dead snapshot {sid} still cached"
