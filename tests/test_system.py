"""End-to-end behaviour tests: training loop, fault tolerance, dry-run,
trace realism, and the paper's headline claim at reduced scale."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.configs import ARCHS, get_config
from repro.launch.train import train
from repro.traces import eager


class TestTrainingLoop:
    def test_loss_decreases(self, tmp_path):
        out = train("qwen3-1.7b", steps=60, seq=64, batch=8,
                    ckpt_dir=str(tmp_path), ckpt_every=20,
                    peak_lr=5e-3, monitor=True)
        assert out["status"] == "done"
        assert out["final_loss"] < out["first_loss"] - 0.1
        assert len(out["rss_trace_gb"]) >= 1

    def test_kill_and_resume_is_consistent(self, tmp_path):
        """Preemption at step 20 + resume == same data path (deterministic
        pipeline) and training continues from the checkpoint."""
        d = str(tmp_path / "ck")
        out1 = train("mamba2-780m", steps=40, seq=32, batch=4, ckpt_dir=d,
                     ckpt_every=10, kill_at_step=20, monitor=False)
        assert out1["status"] == "killed"
        out2 = train("mamba2-780m", steps=40, seq=32, batch=4, ckpt_dir=d,
                     resume=True, ckpt_every=10, monitor=False)
        assert out2["status"] == "done"
        assert np.isfinite(out2["final_loss"])


class TestCellPolicy:
    def test_cell_counts(self):
        total = runnable = 0
        for a in ARCHS:
            cfg = get_config(a)
            for s in SHAPES:
                total += 1
                ok, why = cell_supported(cfg, s)
                runnable += ok
                if not ok:
                    assert why  # documented reason
        assert total == 40
        assert runnable == 31

    def test_long_context_policy(self):
        assert cell_supported(get_config("mamba2-780m"), "long_500k")[0]
        assert cell_supported(get_config("zamba2-2.7b"), "long_500k")[0]
        assert not cell_supported(get_config("llama3-8b"), "long_500k")[0]
        assert not cell_supported(get_config("hubert-xlarge"), "decode_32k")[0]

    @pytest.mark.parametrize("arch", ARCHS)
    def test_input_specs_build(self, arch):
        cfg = get_config(arch)
        for s in SHAPES:
            if not cell_supported(cfg, s)[0]:
                continue
            specs = input_specs(cfg, s)
            assert "batch" in specs
            cell = SHAPES[s]
            lead = [v.shape[0] for v in specs["batch"].values()]
            assert all(x == cell.batch for x in lead)


class TestDryRunTinyMesh:
    """Real lower+compile on a forced 8-device host (subprocess so the main
    test process keeps its single-device view)."""

    @pytest.mark.parametrize("arch,shape", [
        ("qwen3-1.7b", "train_4k"),
        ("olmoe-1b-7b", "decode_32k"),
        ("mamba2-780m", "long_500k"),
    ])
    def test_compiles_on_tiny_mesh(self, arch, shape, tmp_path):
        code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
import repro.launch.mesh as mesh_mod
mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 2) if multi_pod else (4, 2),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
from repro.configs import get_config
from repro.launch import dryrun
dryrun.make_production_mesh = mesh_mod.make_production_mesh
cfg = get_config("{arch}")
# shrink the global batch to fit an 8-device toy mesh
import repro.launch.shapes as shp
cell = shp.SHAPES["{shape}"]
shp.SHAPES["{shape}"] = dataclasses.replace(cell, batch=max(cell.batch // 32, 4))
rec = dryrun.run_cell("{arch}", "{shape}", False, out_dir="{tmp_path}")
assert rec["status"] == "ok", rec
rec2 = dryrun.run_cell("{arch}", "{shape}", True, out_dir="{tmp_path}")
assert rec2["status"] == "ok", rec2
print("TINY-MESH-OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", code], cwd=os.getcwd(),
                           env=env, capture_output=True, text=True,
                           timeout=540)
        assert "TINY-MESH-OK" in r.stdout, r.stdout + r.stderr


class TestTraceRealism:
    def test_eager_statistics_match_paper(self):
        wf = eager(30)
        data = wf.generate(seed=0)
        peaks = [e.peak for ex in data.values() for e in ex]
        assert 1.6 < float(np.mean(peaks)) < 3.2   # paper: 2.31 GB
        bwa = [e.peak for e in data["bwa"]]
        assert 9.0 < float(np.median(bwa)) < 12.5  # paper: ~10.6 GB

    def test_split_is_seeded(self):
        wf = eager(10)
        t1, _ = wf.split(seed=3, train_frac=0.5)
        t2, _ = wf.split(seed=3, train_frac=0.5)
        for f in t1:
            assert len(t1[f]) == len(t2[f])
            np.testing.assert_array_equal(t1[f][0].mem, t2[f][0].mem)
