"""Tests for the `repro.workloads` subsystem + DAG-aware ClusterSim.

Covers the PR-5 acceptance surface:

* seeded-generator determinism — same seed => bitwise-identical packed
  lanes, straight off the :class:`FleetBatch` buckets;
* wfcommons importer — mini checked-in instance parses to the right DAG,
  export/import round-trips, malformed graphs raise naming the task ids;
* DAG-aware replay — fused/packed/legacy engines agree decision for
  decision on DAG workloads, and the fused engine's placements are pinned
  against a from-scratch topological-order oracle written here;
* per-lane ``last_peak_bump`` — retry_packed / fleet / ClusterSim accept
  per-family bumps and match scalar-bump oracles lane for lane;
* hetero-dt warning dedup — one :class:`HeteroDtWarning` per process for
  an N-family hetero-dt workload.
"""

import heapq
import itertools
import json
import os
import warnings

import numpy as np
import pytest

from repro.core import (
    AllocationPlan,
    RetrySpec,
    ksplus_retry,
    retry_packed,
    simulate_execution,
    simulate_fleet,
)
from repro.core.ksplus import (
    HeteroDtWarning,
    KSPlusAuto,
    reset_hetero_dt_warnings,
)
from repro.sched import ClusterSim, Job, Node, OffsetCandidate
from repro.sched.cluster import ADMIT_GRID
from repro.workloads import (
    FamilyRecipe,
    assert_release_order,
    barrier_parents,
    chain_parents,
    fanout_parents,
    layered_parents,
    scenarios,
    synthesize,
    wfc,
)

DATA = os.path.join(os.path.dirname(__file__), "data")


def _batch_arrays(wf):
    return [(b.idx, b.mems, b.lengths) for b in wf.batch.buckets]


# ------------------------------------------------------------ generator
class TestGeneratorDeterminism:
    def test_same_seed_bitwise_identical_lanes(self):
        a = scenarios.get("heavy_tail", n_tasks=120, seed=11)
        b = scenarios.get("heavy_tail", n_tasks=120, seed=11)
        for (ia, ma, la), (ib, mb, lb) in zip(_batch_arrays(a),
                                              _batch_arrays(b)):
            assert np.array_equal(ia, ib)
            assert np.array_equal(la, lb)
            assert np.array_equal(ma, mb)  # bitwise
        assert np.array_equal(a.input_gb, b.input_gb)
        assert a.parents == b.parents

    def test_different_seed_differs(self):
        a = scenarios.get("heavy_tail", n_tasks=60, seed=0)
        b = scenarios.get("heavy_tail", n_tasks=60, seed=1)
        assert not np.array_equal(a.input_gb, b.input_gb)

    def test_shapes_have_their_structure(self):
        recipes = [
            FamilyRecipe("flat", shape="plateau", noise=0.0,
                         mem_sigma=0.0, dur_sigma=0.0),
            FamilyRecipe("spiky", shape="spike", noise=0.0, mem_sigma=0.0,
                         dur_sigma=0.0, spike_gain=2.0),
            FamilyRecipe("steps", shape="phases", noise=0.0, mem_sigma=0.0,
                         dur_sigma=0.0, n_phases=3.0),
        ]
        wf = synthesize(recipes, 8, seed=0)
        by_fam = {}
        for i, f in enumerate(wf.families):
            by_fam.setdefault(f, []).append(wf.mem(i))
        for m in by_fam["flat"]:  # flat: constant
            assert np.ptp(m) < 1e-6 * m.max()
        for m in by_fam["spiky"]:  # spike: short excursion at ~2x
            assert m.max() > 1.8 * np.median(m)
        for m in by_fam["steps"]:  # phases: ascending steps
            assert len(np.unique(np.round(m, 5))) == 3
            assert m[0] < m[-1]

    def test_input_size_scaling(self):
        wf = synthesize(
            [FamilyRecipe("scaled", dur_base=5.0, dur_per_gb=30.0,
                          input_sigma=0.8, dur_sigma=0.0)], 64, seed=2)
        order = np.argsort(wf.input_gb)
        # durations follow input size (no duration noise in this recipe)
        assert np.all(np.diff(wf.lengths[order]) >= 0)

    def test_identical_recipes_draw_independent_tasks(self):
        """Two recipes sharing (name, shape, dt) must not reuse RNG draws
        — the recipe position is folded into the key."""
        wf = synthesize(
            [FamilyRecipe("a"), FamilyRecipe("a", mem_base=9.0)], 5, seed=0)
        assert not np.array_equal(wf.input_gb[:5], wf.input_gb[5:])

    def test_tiny_scenario_keeps_every_family(self):
        """Degenerate n_tasks is clamped so no family silently drops."""
        wf = scenarios.get("heavy_tail", n_tasks=1, seed=0)
        assert set(wf.families) == {"mice", "elephants", "saw_io"}
        assert wf.B == 3  # one task per family, never negative counts

    def test_ten_k_tasks_materialize(self):
        wf = scenarios.get("workload_replay", n_tasks=10_000, seed=0)
        assert wf.B == 10_000
        assert len(wf.batch.buckets) <= 4  # a few batched dispatches
        assert sum(len(b.idx) for b in wf.batch.buckets) == 10_000


class TestDagBuilders:
    def test_builders_are_valid_dags(self):
        for parents in (chain_parents(40, 4), fanout_parents(40, 8),
                        layered_parents(40, seed=0, layer_width=8),
                        barrier_parents(40, waves=5)):
            ids = [str(i) for i in range(len(parents))]
            wfc.validate_dag_ids(
                ids, [[str(p) for p in ps] for ps in parents])

    def test_chain_depth_and_fanout_width(self):
        ch = chain_parents(12, chains=3)
        assert ch[0] == () and ch[3] == (0,) and ch[11] == (8,)
        fo = fanout_parents(9, fanout=8)
        assert fo[0] == () and all(fo[i] == (0,) for i in range(1, 9))


# ------------------------------------------------------------- wfcommons
class TestWfcImporter:
    def _doc(self):
        with open(os.path.join(DATA, "mini_wfcommons.json")) as f:
            return json.load(f)

    def test_mini_instance_imports(self):
        wf = wfc.load_instance(os.path.join(DATA, "mini_wfcommons.json"))
        assert wf.B == 6
        assert wf.families == ["split", "blast", "blast", "blast",
                               "merge", "report"]
        assert wf.parents == ((), (0,), (0,), (0,), (1, 2, 3), (4,))
        assert list(wf.lengths) == [12, 64, 58, 71, 25, 8]
        np.testing.assert_allclose(
            wf.peaks(), [0.5, 4.0, 3.0, 5.0, 2.0, 0.25], rtol=1e-6)

    def test_round_trip(self):
        wf = wfc.import_instance(self._doc())
        again = wfc.import_instance(wfc.export_instance(wf))
        assert again.task_ids == wf.task_ids
        assert again.parents == wf.parents
        assert again.families == wf.families
        assert np.array_equal(again.lengths, wf.lengths)
        np.testing.assert_array_equal(again.peaks(), wf.peaks())

    def test_legacy_layout(self):
        doc = {"name": "legacy", "workflow": {"tasks": [
            {"name": "a_001", "runtime": 10.0, "memory": 2 ** 30,
             "parents": []},
            {"name": "b_002", "runtime": 5.0, "memory": 2 ** 29,
             "parents": ["a_001"]},
        ]}}
        wf = wfc.import_instance(doc)
        assert wf.parents == ((), (0,))
        assert wf.families == ["a", "b"]

    def test_legacy_parents_by_name_with_distinct_ids(self):
        """Legacy parents reference task *names*; ids may differ."""
        doc = {"workflow": {"tasks": [
            {"id": "ID01", "name": "split_001", "runtime": 10.0,
             "memory": 2 ** 30, "parents": []},
            {"id": "ID02", "name": "blast_002", "runtime": 5.0,
             "memory": 2 ** 29, "parents": ["split_001"]},
        ]}}
        wf = wfc.import_instance(doc)
        assert wf.task_ids == ["ID01", "ID02"]
        assert wf.parents == ((), (0,))

    def test_missing_measurements_raise(self):
        doc = self._doc()
        doc["workflow"]["execution"]["tasks"].pop(2)  # drop one entry
        with pytest.raises(ValueError,
                           match="runtime/memory.*blast_00000003"):
            wfc.import_instance(doc)
        legacy = {"workflow": {"tasks": [
            {"name": "a_001", "parents": []}]}}  # no runtime/memory
        with pytest.raises(ValueError, match="runtime.*a_001"):
            wfc.import_instance(legacy)

    def test_cycle_raises_with_ids(self):
        doc = self._doc()
        tasks = doc["workflow"]["specification"]["tasks"]
        tasks[0]["parents"] = ["report_00000006"]  # close the loop
        with pytest.raises(ValueError, match="cycle.*split_00000001"):
            wfc.import_instance(doc)

    def test_self_parent_raises_with_id(self):
        doc = self._doc()
        doc["workflow"]["specification"]["tasks"][1]["parents"] = [
            "blast_00000002"]
        with pytest.raises(ValueError, match="own parent.*blast_00000002"):
            wfc.import_instance(doc)

    def test_unknown_parent_raises(self):
        doc = self._doc()
        doc["workflow"]["specification"]["tasks"][1]["parents"] = ["nope"]
        with pytest.raises(ValueError, match="unknown parent.*nope"):
            wfc.import_instance(doc)

    def test_duplicate_ids_raise(self):
        doc = self._doc()
        tasks = doc["workflow"]["specification"]["tasks"]
        tasks[2]["id"] = tasks[1]["id"]
        with pytest.raises(ValueError, match="duplicate.*blast_00000002"):
            wfc.import_instance(doc)

    def test_not_an_instance_raises(self):
        with pytest.raises(ValueError, match="missing 'workflow'"):
            wfc.import_instance({"nope": 1})
        with pytest.raises(ValueError, match="specification"):
            wfc.import_instance({"workflow": {}})


# --------------------------------------------------- ClusterSim validation
def _tiny_job(jid, parents=(), peak=4.0, L=10):
    return Job(jid=jid, family="t", input_gb=1.0,
               mem=np.full(L, 1.0), dt=1.0,
               plan=AllocationPlan(np.zeros(1), np.asarray([peak])),
               est_runtime=float(L), parents=tuple(parents))


class TestClusterDagValidation:
    @pytest.mark.parametrize("engine", ["legacy", "packed", "fused"])
    def test_self_parent_rejected_loudly(self, engine):
        jobs = [_tiny_job(0), _tiny_job(7, parents=(7,))]
        sim = ClusterSim([Node(0, 16.0)], engine=engine)
        with pytest.raises(ValueError, match=r"own parent.*\[7\]"):
            sim.run(jobs, RetrySpec("ksplus"))

    @pytest.mark.parametrize("engine", ["legacy", "packed", "fused"])
    def test_cycle_rejected_loudly(self, engine):
        jobs = [_tiny_job(0, parents=(1,)), _tiny_job(1, parents=(0,)),
                _tiny_job(2)]
        sim = ClusterSim([Node(0, 16.0)], engine=engine)
        with pytest.raises(ValueError, match=r"cycle.*\[0, 1\]"):
            sim.run(jobs, RetrySpec("ksplus"))

    def test_unknown_parent_rejected(self):
        jobs = [_tiny_job(0), _tiny_job(1, parents=(42,))]
        with pytest.raises(ValueError, match="unknown parent.*42"):
            ClusterSim([Node(0, 16.0)]).run(jobs, RetrySpec("ksplus"))

    def test_duplicate_jids_rejected_when_dag(self):
        jobs = [_tiny_job(3), _tiny_job(3), _tiny_job(4, parents=(3,))]
        with pytest.raises(ValueError, match=r"duplicate.*\[3\]"):
            ClusterSim([Node(0, 16.0)]).run(jobs, RetrySpec("ksplus"))

    def test_parent_free_jobs_unchanged(self):
        """No parents anywhere -> the historical no-frontier behavior."""
        jobs = [_tiny_job(i) for i in range(4)]
        res = ClusterSim([Node(0, 16.0)]).run(jobs, RetrySpec("ksplus"))
        assert len(res.placements) == 4
        assert res.placements[0][0] == 0.0


# -------------------------------------------------- DAG replay differential
def _dag_jobs(scenario, n, seed=0, under_frac=0.25):
    wf = scenarios.get(scenario, n_tasks=n, seed=seed)
    return wf.to_jobs(under_frac=under_frac, seed=seed)


def _nodes():
    return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0)]


def _topo_oracle(jobs, caps, retry_fn, max_attempts=20):
    """From-scratch topological-order replay oracle.

    Independent of ClusterSim's engines: explicit topological release
    bookkeeping, per-decision recomputation of node residuals with
    :func:`repro.core.alloc_at`, greedy first-fit in (queue, node) order,
    an event heap with submission-order tie-breaks.  Returns the
    placement log (t, node, jid) plus retry/unschedulable counts.
    """
    from repro.core import alloc_at, first_violation

    index = {j.jid: i for i, j in enumerate(jobs)}
    pend = [len(set(j.parents)) for j in jobs]
    children = [[] for _ in jobs]
    for i, j in enumerate(jobs):
        for p in dict.fromkeys(j.parents):
            children[index[p]].append(i)
    dead = [False] * len(jobs)
    plans = [j.plan for j in jobs]
    attempts = [0] * len(jobs)
    running = [[] for _ in caps]          # (start_t, job index)
    ready = [i for i in range(len(jobs)) if pend[i] == 0]
    events = []
    seq = itertools.count()
    placements, retries, unschedulable = [], 0, 0

    def fits(ni, i, now):
        horizon = now + np.linspace(0, jobs[i].est_runtime, ADMIT_GRID)
        used = np.zeros_like(horizon)
        for (s, r) in running[ni]:
            rel = horizon - s
            active = (rel >= 0) & (rel < jobs[r].runtime + 1e-9)
            used += np.where(active, alloc_at(plans[r], np.maximum(rel, 0)),
                             0.0)
        need = alloc_at(plans[i],
                        np.linspace(0, jobs[i].est_runtime, ADMIT_GRID))
        return bool(np.all(need <= caps[ni] - used + 1e-9))

    def admit(now):
        progressed = True
        while progressed and ready:
            progressed = False
            for i in list(ready):
                for ni in range(len(caps)):
                    if fits(ni, i, now):
                        ready.remove(i)
                        running[ni].append((now, i))
                        placements.append((float(now), ni, jobs[i].jid))
                        v = first_violation(plans[i], jobs[i].mem,
                                            jobs[i].dt)
                        end = (now + jobs[i].runtime if v < 0
                               else now + v * jobs[i].dt)
                        heapq.heappush(
                            events, (end, next(seq),
                                     "done" if v < 0 else "oom", ni, i))
                        progressed = True
                        break

    admit(0.0)
    while events:
        t, _, kind, ni, i = heapq.heappop(events)
        running[ni] = [(s, r) for s, r in running[ni] if r != i]
        if kind == "done":
            for c in children[i]:
                pend[c] -= 1
                if pend[c] == 0 and not dead[c]:
                    ready.append(c)
        else:
            attempts[i] += 1
            retries += 1
            if attempts[i] >= max_attempts or \
                    float(np.max(jobs[i].mem)) > max(caps):
                unschedulable += 1
                stack = list(children[i])
                while stack:
                    c = stack.pop()
                    if not dead[c]:
                        dead[c] = True
                        unschedulable += 1
                        stack.extend(children[c])
            else:
                v = first_violation(plans[i], jobs[i].mem, jobs[i].dt)
                plans[i] = retry_fn(plans[i], v * jobs[i].dt,
                                    float(jobs[i].mem[v]))
                ready.append(i)
        admit(t)
    return placements, retries, unschedulable


class TestDagReplayDifferential:
    @pytest.mark.parametrize("scenario", ["burst_arrival", "deep_chain",
                                          "wide_fanout"])
    def test_engines_agree_on_dag_workloads(self, scenario):
        legacy = ClusterSim(_nodes(), engine="legacy").run(
            _dag_jobs(scenario, 120), ksplus_retry)
        packed = ClusterSim(_nodes(), engine="packed").run(
            _dag_jobs(scenario, 120), RetrySpec("ksplus"))
        fused = ClusterSim(_nodes(), engine="fused").run(
            _dag_jobs(scenario, 120), RetrySpec("ksplus"))
        assert legacy.retries > 0  # workload exercises OOM under a DAG
        for res in (packed, fused):
            assert res.placements == legacy.placements
            assert res.retries == legacy.retries
            assert res.unschedulable == legacy.unschedulable
            assert res.makespan == legacy.makespan
            np.testing.assert_allclose(
                res.total_wastage_gbs, legacy.total_wastage_gbs, rtol=1e-6)

    def test_fused_matches_topological_oracle(self):
        jobs = _dag_jobs("burst_arrival", 150, seed=4)
        fused = ClusterSim(_nodes(), engine="fused").run(
            _dag_jobs("burst_arrival", 150, seed=4), RetrySpec("ksplus"))
        caps = [n.capacity_gb for n in _nodes()]
        oracle_pl, oracle_re, oracle_un = _topo_oracle(
            jobs, caps, ksplus_retry)
        oracle = [(t, _nodes()[ni].nid, jid) for t, ni, jid in oracle_pl]
        assert fused.placements == oracle
        assert fused.retries == oracle_re
        assert fused.unschedulable == oracle_un

    def test_release_order_enforced(self):
        wf = scenarios.get("wide_fanout", n_tasks=100, seed=2)
        jobs = wf.to_jobs(under_frac=0.2, seed=2)
        res = ClusterSim(_nodes(), engine="fused").run(
            jobs, RetrySpec("ksplus"))
        assert_release_order(jobs, res.placements)
        # root placed first, alone; nothing else until it finishes
        first_t = res.placements[0][0]
        assert [p for p in res.placements if p[0] == first_t] == \
            [res.placements[0]]

    def test_release_order_checker_catches_violations(self):
        jobs = [_tiny_job(0, L=10), _tiny_job(1, parents=(0,), L=10)]
        with pytest.raises(AssertionError, match="before"):
            assert_release_order(jobs, [(0.0, 0, 0), (5.0, 0, 1)])
        with pytest.raises(AssertionError, match="never"):
            assert_release_order(jobs, [(0.0, 0, 1)])

    @pytest.mark.parametrize("engine", ["legacy", "packed", "fused"])
    def test_doomed_descendants_counted(self, engine):
        """An unsatisfiable root dooms its chain: every descendant counts
        unschedulable and is never placed — identically on all engines."""
        big = _tiny_job(0, peak=8.0, L=12)
        big.mem = np.full(12, 100.0)  # above every node's capacity
        chain = [big] + [_tiny_job(i, parents=(i - 1,), L=8)
                         for i in range(1, 5)]
        free = [_tiny_job(10 + i, L=6) for i in range(3)]
        retry = (ksplus_retry if engine == "legacy"
                 else RetrySpec("ksplus"))
        res = ClusterSim(_nodes(), engine=engine).run(chain + free, retry)
        assert res.unschedulable == 5  # the root + 4 doomed descendants
        placed = {jid for _, _, jid in res.placements}
        assert placed == {0, 10, 11, 12}  # chain tail never admitted

    def test_offset_sweep_on_dag_workload(self):
        """Offset sweeps and DAG release compose (fresh frontier per
        candidate)."""
        jobs = _dag_jobs("deep_chain", 80, seed=1)
        results = ClusterSim(_nodes()).run(
            jobs, RetrySpec("ksplus"),
            offsets=[OffsetCandidate(), OffsetCandidate(peak=0.10)])
        assert len(results) == 2
        base = ClusterSim(_nodes()).run(
            _dag_jobs("deep_chain", 80, seed=1), RetrySpec("ksplus"))
        assert results[0].placements == base.placements


# ----------------------------------------------------------- per-lane bump
class TestPerLaneBump:
    def _packed_plans(self, B, seed=0):
        rng = np.random.default_rng(seed)
        starts = np.sort(rng.uniform(0, 50, (B, 3)), axis=1)
        starts[:, 0] = 0.0
        peaks = np.sort(rng.uniform(1, 8, (B, 3)), axis=1)
        nseg = np.full((B,), 3, np.int64)
        return starts, peaks, nseg

    def test_retry_packed_per_lane_bump_matches_scalar_loop(self):
        B = 16
        starts, peaks, nseg = self._packed_plans(B)
        rng = np.random.default_rng(1)
        t_fail = rng.uniform(40, 60, B)  # fails inside the last segment
        used = rng.uniform(5, 9, B)
        bump = rng.uniform(0.1, 0.9, B)
        ns, np_ = retry_packed(RetrySpec("ksplus"), starts, peaks, nseg,
                               t_fail, used, bump=bump)
        for i in range(B):
            si, pi = retry_packed(
                RetrySpec("ksplus", bump=float(bump[i])),
                starts[i:i + 1], peaks[i:i + 1], nseg[i:i + 1],
                t_fail[i:i + 1], used[i:i + 1])
            np.testing.assert_array_equal(ns[i], si[0])
            np.testing.assert_array_equal(np_[i], pi[0])

    def test_fleet_bump_lanes_match_per_execution_oracle(self):
        rng = np.random.default_rng(3)
        B, L = 24, 40
        mems, plans, bumps = [], [], []
        for i in range(B):
            lo, hi = rng.uniform(1, 2), rng.uniform(4, 7)
            split = int(rng.uniform(0.4, 0.7) * L)
            mem = np.concatenate([np.full(split, lo), np.full(L - split, hi)])
            mems.append(mem)
            # under-allocate the last segment so the ksplus bump matters
            plans.append(AllocationPlan(
                np.asarray([0.0, float(max(split - 1, 1))]),
                np.asarray([lo * 1.1, hi * 0.8])))
            bumps.append(float(rng.choice([0.15, 0.45, 0.9])))
        bumps = np.asarray(bumps)
        fr = simulate_fleet(plans, RetrySpec("ksplus"), mems, 1.0,
                            machine_memory=64.0, bump_lanes=bumps)
        for i in range(B):
            res = simulate_execution(
                plans[i],
                lambda p, t, u, _b=bumps[i]: ksplus_retry(
                    p, t, u, last_peak_bump=_b),
                mems[i], 1.0, machine_memory=64.0)
            assert fr.attempts[i] == res.num_retries + 1
            assert fr.succeeded[i] == res.succeeded
            np.testing.assert_allclose(fr.wastage_gbs[i], res.wastage_gbs,
                                       rtol=2e-5)
        assert fr.retries.sum() > 0

    def _two_family_jobs(self, seed=0):
        rng = np.random.default_rng(seed)
        jobs = []
        for j in range(40):
            L = int(rng.integers(20, 60))
            split = int(rng.uniform(0.4, 0.7) * L)
            lo, hi = rng.uniform(1.5, 3.0), rng.uniform(5.0, 10.0)
            mem = np.concatenate([np.full(split, lo), np.full(L - split, hi)])
            under = rng.uniform() < 0.4
            plan = AllocationPlan(
                np.asarray([0.0, max(split - 2.0, 1.0)]),
                np.asarray([lo * 1.15, hi * (0.9 if under else 1.12)]))
            jobs.append(Job(jid=j, family=("a" if j % 2 else "b"),
                            input_gb=1.0, mem=mem, dt=1.0, plan=plan,
                            est_runtime=float(L)))
        return jobs

    def test_cluster_family_bumps_may_disagree(self):
        """Per-family offsets with different last_peak_bump values run in
        ONE replay and agree across the packed and fused engines."""
        mapping = {"a": OffsetCandidate(last_peak_bump=0.9),
                   "b": OffsetCandidate(peak=0.05, last_peak_bump=0.15)}
        packed = ClusterSim(_nodes(), engine="packed").run(
            self._two_family_jobs(), RetrySpec("ksplus"), offsets=mapping)
        fused = ClusterSim(_nodes(), engine="fused").run(
            self._two_family_jobs(), RetrySpec("ksplus"), offsets=mapping)
        assert packed.retries > 0
        assert fused.placements == packed.placements
        assert fused.retries == packed.retries
        np.testing.assert_allclose(fused.total_wastage_gbs,
                                   packed.total_wastage_gbs, rtol=1e-9)

    def test_uniform_family_bump_equals_scalar_candidate(self):
        """A mapping whose bumps all agree reproduces the scalar-bump
        sweep path decision for decision."""
        mapping = {"a": OffsetCandidate(last_peak_bump=0.5),
                   "b": OffsetCandidate(last_peak_bump=0.5)}
        via_map = ClusterSim(_nodes()).run(
            self._two_family_jobs(), RetrySpec("ksplus"), offsets=mapping)
        via_scalar = ClusterSim(_nodes()).run(
            self._two_family_jobs(), RetrySpec("ksplus"),
            offsets=[OffsetCandidate(last_peak_bump=0.5)])[0]
        assert via_map.placements == via_scalar.placements
        assert via_map.retries == via_scalar.retries
        np.testing.assert_allclose(via_map.total_wastage_gbs,
                                   via_scalar.total_wastage_gbs, rtol=1e-12)

    def test_tune_offset_map_feeds_cluster(self):
        from repro.core import KSPlus, registry

        wf = scenarios.get("heavy_tail", n_tasks=60, seed=5)
        data, fitted = {}, {}
        for fam in set(wf.families):
            idx = [i for i, f in enumerate(wf.families) if f == fam]
            mems = [wf.mem(i) for i in idx]
            dts = [wf.dts[i] for i in idx]
            inputs = [wf.input_gb[i] for i in idx]
            m = KSPlus(k=3)
            m.fit(mems, dts, inputs)
            fitted[fam], data[fam] = m, (mems, dts, inputs)
        mapping = registry.tune_offset_map(fitted, data,
                                           machine_memory=64.0)
        assert set(mapping) == set(fitted)
        res = ClusterSim(_nodes()).run(
            wf.to_jobs(under_frac=0.2, seed=5), RetrySpec("ksplus"),
            offsets=mapping)
        assert res.offset is not None  # per-lane candidate applied


# ------------------------------------------------- hetero-dt warning dedup
class TestHeteroDtWarningDedup:
    def test_one_warning_for_many_family_fits(self):
        wf = scenarios.get("hetero_dt", n_tasks=64, seed=0)
        idx = [i for i, f in enumerate(wf.families) if f == "mixed"]
        mems = [wf.mem(i) for i in idx]
        dts = [float(wf.dts[i]) for i in idx]
        inputs = [float(wf.input_gb[i]) for i in idx]
        assert len(set(dts)) > 1  # the scenario really mixes dts
        reset_hetero_dt_warnings()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            for _ in range(5):  # five per-family fits, one situation
                auto = KSPlusAuto(candidates=(2, 3))
                auto.fit(mems, dts, inputs)
        hetero = [w for w in rec if issubclass(w.category, HeteroDtWarning)]
        assert len(hetero) == 1
        # re-armed after reset
        reset_hetero_dt_warnings()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            KSPlusAuto(candidates=(2, 3)).fit(mems, dts, inputs)
        assert sum(issubclass(w.category, HeteroDtWarning)
                   for w in rec) == 1


# ----------------------------------------------- evaluate_workflow adapter
class TestScenarioEvaluation:
    def test_workflow_trace_evaluates(self):
        from repro.sched import evaluate_workflow

        wf = scenarios.get("heavy_tail", n_tasks=90, seed=0)
        res = evaluate_workflow(wf, seed=0, train_frac=0.5,
                                methods=["ks+", "default"])
        assert res.workflow == "heavy_tail"
        assert set(res.methods) == {"ks+", "default"}
        assert res.methods["ks+"].total_gbs > 0
        assert set(res.methods["ks+"].per_family_gbs) == set(wf.families)

    def test_scenario_names_resolve(self):
        assert set(scenarios.scenario_names()) >= {
            "burst_arrival", "heavy_tail", "deep_chain", "wide_fanout",
            "hetero_dt", "workload_replay"}
        with pytest.raises(KeyError, match="unknown scenario"):
            scenarios.get("nope")

    def test_split_is_seeded_and_disjoint(self):
        wf = scenarios.get("heavy_tail", n_tasks=60, seed=0).to_workflow()
        tr1, te1 = wf.split(3, 0.5)
        tr2, te2 = wf.split(3, 0.5)
        for f in tr1:
            assert len(tr1[f]) == len(tr2[f])
            assert len(tr1[f]) + len(te1[f]) == len(tr1[f] + te1[f])
            ids1 = [id(e) for e in tr1[f]]
            assert ids1 == [id(e) for e in tr2[f]]
