"""Differential tests: batched fleet engine vs the per-execution oracle.

The engine (`repro.core.fleet`) must reproduce `simulate_execution`
attempt-for-attempt: identical retry counts and success flags, wastage equal
within float32 accumulation tolerance — across every method's retry rule,
several seeds, and the protocol's edge cases (unsatisfiable traces,
single-sample traces, retries inside the last segment).
"""

import numpy as np
import pytest

from repro.core import (
    AllocationPlan,
    DefaultMethod,
    KSegments,
    KSPlus,
    KSPlusAuto,
    PPMImproved,
    RetrySpec,
    TovarPPM,
    WittPercentile,
    concat_packed,
    ksplus_retry,
    pack_plans,
    packed_predict,
    simulate_execution,
    simulate_fleet,
    simulate_fleet_many,
)
from repro.core.fleet import bucket_traces
from repro.sched.simulator import evaluate_workflow
from repro.traces import eager, sarek

MACHINE = 128.0
WTOL = dict(rtol=5e-4, atol=5e-2)


def _assert_lane_matches(fr, i, res, ctx=""):
    assert res.num_retries == fr.retries[i], \
        f"{ctx}: retries {res.num_retries} != {fr.retries[i]}"
    assert res.succeeded == bool(fr.succeeded[i]), f"{ctx}: succeeded"
    np.testing.assert_allclose(
        fr.wastage_gbs[i], res.wastage_gbs, err_msg=ctx, **WTOL)


def _method_zoo(machine, limit=8.0, k=4):
    return {
        "ks+": KSPlus(k=k),
        "ks+auto": KSPlusAuto(machine_memory=machine, candidates=(2, 3, 4)),
        "k-segments-selective": KSegments(k=k, variant="selective"),
        "k-segments-partial": KSegments(k=k, variant="partial"),
        "tovar-ppm": TovarPPM(machine_memory=machine),
        "ppm-improved": PPMImproved(machine_memory=machine),
        "witt-p95": WittPercentile(percentile=95.0, machine_memory=machine),
        "default": DefaultMethod(limit_gb=limit, machine_memory=machine),
    }


class TestDifferentialWorkflow:
    """Every method × several seeds on realistic synthetic workloads."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_all_methods_match_oracle(self, seed):
        wf = eager(12)
        train, test = wf.split(seed, 0.5, 1.0)
        for fname in list(train)[:5]:
            te = test[fname]
            if not te:
                continue
            mems = [e.mem for e in train[fname]]
            dts = [e.dt for e in train[fname]]
            inputs = [e.input_gb for e in train[fname]]
            for mname, method in _method_zoo(MACHINE).items():
                method.fit(mems, dts, inputs)
                plans = [method.predict(e.input_gb) for e in te]
                fr = simulate_fleet(
                    plans, method.retry_spec, [e.mem for e in te], 1.0,
                    machine_memory=MACHINE)
                for i, e in enumerate(te):
                    res = simulate_execution(
                        plans[i], method.retry, e.mem, e.dt,
                        machine_memory=MACHINE)
                    _assert_lane_matches(
                        fr, i, res, f"seed={seed} {fname} {mname} lane={i}")

    def test_sarek_spot_check(self):
        wf = sarek(10)
        train, test = wf.split(3, 0.5, 1.0)
        fname = list(train)[1]
        m = KSPlus(k=4)
        m.fit([e.mem for e in train[fname]], [e.dt for e in train[fname]],
              [e.input_gb for e in train[fname]])
        te = test[fname]
        plans = [m.predict(e.input_gb) for e in te]
        fr = simulate_fleet(plans, m.retry_spec, [e.mem for e in te], 1.0,
                            machine_memory=MACHINE)
        for i, e in enumerate(te):
            res = simulate_execution(plans[i], m.retry, e.mem, e.dt,
                                     machine_memory=MACHINE)
            _assert_lane_matches(fr, i, res, f"sarek lane={i}")


class TestEdgeCases:
    def _diff(self, plans, mems, spec, retry, machine=16.0, backend="jnp"):
        fr = simulate_fleet(plans, spec, mems, 1.0, machine_memory=machine,
                            backend=backend)
        for i, (pl, mm) in enumerate(zip(plans, mems)):
            res = simulate_execution(pl, retry, mm, 1.0,
                                     machine_memory=machine)
            _assert_lane_matches(fr, i, res, f"lane={i}")
        return fr

    def test_unsatisfiable_trace(self):
        plan = AllocationPlan(np.zeros(1), np.asarray([2.0]))
        mem = np.full(10, 50.0)  # above machine_memory=16
        fr = self._diff([plan], [mem], RetrySpec("double"),
                        lambda p, t, u: p.with_(
                            peaks=np.minimum(p.peaks * 2, 16.0)))
        assert not fr.succeeded[0]

    def test_single_sample_traces(self):
        plans = [AllocationPlan(np.zeros(1), np.asarray([4.0])),
                 AllocationPlan(np.zeros(1), np.asarray([2.0]))]
        mems = [np.asarray([3.0]), np.asarray([3.0])]  # success / fail+retry
        fr = self._diff(plans, mems, RetrySpec("double"),
                        lambda p, t, u: p.with_(
                            peaks=np.minimum(p.peaks * 2, 16.0)))
        assert fr.succeeded.all() and fr.retries[1] == 1

    def test_retry_inside_last_segment(self):
        plan = AllocationPlan(np.asarray([0.0, 10.0]), np.asarray([2.0, 4.0]))
        mem = np.concatenate([np.full(10, 1.5), np.full(20, 4.5)])
        fr = self._diff([plan], [mem], RetrySpec("ksplus"), ksplus_retry)
        assert fr.succeeded[0] and fr.retries[0] >= 1

    def test_retime_before_last_segment(self):
        plan = AllocationPlan(np.asarray([0.0, 30.0]), np.asarray([2.0, 6.0]))
        mem = np.concatenate([np.full(20, 1.5), np.full(20, 5.0)])
        self._diff([plan], [mem], RetrySpec("ksplus"), ksplus_retry)

    def test_max_attempts_exhaustion(self):
        plan = AllocationPlan(np.zeros(1), np.asarray([2.0]))
        mem = np.full(8, 10.0)  # below machine: retries forever with "none"
        fr = simulate_fleet([plan], RetrySpec("none"), [mem], 1.0,
                            machine_memory=16.0, max_attempts=5)
        res = simulate_execution(plan, lambda p, t, u: p, mem, 1.0,
                                 max_attempts=5, machine_memory=16.0)
        _assert_lane_matches(fr, 0, res, "exhaustion")
        assert not fr.succeeded[0] and fr.attempts[0] == 5

    def test_pallas_backend_matches_jnp(self):
        plans = [AllocationPlan(np.asarray([0.0, 10.0]),
                                np.asarray([2.0, 4.0])),
                 AllocationPlan(np.zeros(1), np.asarray([2.0]))]
        mems = [np.concatenate([np.full(10, 1.5), np.full(20, 4.5)]),
                np.full(12, 3.0)]
        a = simulate_fleet(plans, RetrySpec("ksplus"), mems, 1.0,
                           machine_memory=16.0, backend="jnp")
        b = simulate_fleet(plans, RetrySpec("ksplus"), mems, 1.0,
                           machine_memory=16.0, backend="pallas-interpret")
        np.testing.assert_array_equal(a.attempts, b.attempts)
        np.testing.assert_array_equal(a.succeeded, b.succeeded)
        np.testing.assert_allclose(a.wastage_gbs, b.wastage_gbs, rtol=1e-5)


class TestPackedPredict:
    """Vectorized prediction must equal per-input prediction bit-for-bit."""

    def test_matches_per_plan(self):
        wf = eager(12)
        train, _ = wf.split(0, 0.5, 1.0)
        fname = list(train)[0]
        mems = [e.mem for e in train[fname]]
        dts = [e.dt for e in train[fname]]
        inputs = [e.input_gb for e in train[fname]]
        for method in _method_zoo(MACHINE).values():
            method.fit(mems, dts, inputs)
            packed = packed_predict(method, inputs)
            ref = pack_plans([method.predict(i) for i in inputs])
            np.testing.assert_array_equal(packed[0], ref[0])
            np.testing.assert_array_equal(packed[1], ref[1])
            np.testing.assert_array_equal(packed[2], ref[2])


class TestFleetMany:
    def test_jobs_share_traces(self):
        wf = eager(10)
        train, test = wf.split(1, 0.5, 1.0)
        fname = list(train)[0]
        te = test[fname]
        mems = [e.mem for e in train[fname]]
        dts = [e.dt for e in train[fname]]
        inputs = [e.input_gb for e in train[fname]]
        zoo = _method_zoo(MACHINE)
        jobs, methods = [], []
        for method in zoo.values():
            method.fit(mems, dts, inputs)
            jobs.append((
                packed_predict(method, [e.input_gb for e in te]),
                method.retry_spec))
            methods.append(method)
        traces = bucket_traces([e.mem for e in te])
        results = simulate_fleet_many(jobs, traces, 1.0,
                                      machine_memory=MACHINE)
        for method, fr in zip(methods, results):
            single = simulate_fleet(
                [method.predict(e.input_gb) for e in te],
                method.retry_spec, [e.mem for e in te], 1.0,
                machine_memory=MACHINE)
            np.testing.assert_array_equal(fr.attempts, single.attempts)
            np.testing.assert_allclose(
                fr.wastage_gbs, single.wastage_gbs, rtol=1e-6)


class TestKSPlusAutoFleet:
    def test_fleet_fit_matches_oracle_fit(self):
        wf = eager(12)
        train, _ = wf.split(0, 0.5, 1.0)
        fname = list(train)[0]
        mems = [e.mem for e in train[fname]]
        dts = [e.dt for e in train[fname]]
        inputs = [e.input_gb for e in train[fname]]
        auto_f = KSPlusAuto(machine_memory=MACHINE, candidates=(2, 3, 4))
        auto_o = KSPlusAuto(machine_memory=MACHINE, candidates=(2, 3, 4),
                            engine="oracle")
        auto_f.fit(mems, dts, inputs)
        auto_o.fit(mems, dts, inputs)
        assert auto_f.chosen_k == auto_o.chosen_k

    def test_predict_before_fit_raises(self):
        auto = KSPlusAuto()
        with pytest.raises(RuntimeError, match="fit"):
            auto.predict(1.0)
        with pytest.raises(RuntimeError, match="fit"):
            auto.retry(AllocationPlan(np.zeros(1), np.ones(1)), 1.0, 1.0)


class TestEvaluateWorkflowEngines:
    def test_fleet_matches_oracle_aggregates(self):
        wf = eager(10)
        rf = evaluate_workflow(wf, seed=0, train_frac=0.5, k=4,
                               machine_memory=MACHINE)
        ro = evaluate_workflow(wf, seed=0, train_frac=0.5, k=4,
                               machine_memory=MACHINE, engine="oracle")
        for m in rf.methods:
            a, b = rf.methods[m], ro.methods[m]
            assert a.retries == b.retries, m
            assert a.failures == b.failures, m
            np.testing.assert_allclose(a.total_gbs, b.total_gbs,
                                       rtol=1e-4, err_msg=m)
            for fam in a.per_family_gbs:
                np.testing.assert_allclose(
                    a.per_family_gbs[fam], b.per_family_gbs[fam],
                    rtol=1e-4, atol=1e-2, err_msg=f"{m}/{fam}")


class TestLowLevelEngine:
    """Direct coverage of the standalone jitted entry points."""

    def _packed(self):
        import jax.numpy as jnp
        plans = [AllocationPlan(np.asarray([0.0, 10.0]),
                                np.asarray([2.0, 4.0])),
                 AllocationPlan(np.zeros(1), np.asarray([4.0]))]
        mems = [np.concatenate([np.full(10, 1.5), np.full(22, 4.5)]),
                np.full(16, 3.0)]
        T = 32
        padded = np.zeros((2, T), np.float32)
        lengths = np.zeros((2,), np.int32)
        for i, m in enumerate(mems):
            padded[i, : len(m)] = m
            lengths[i] = len(m)
        starts, peaks, nseg = pack_plans(plans)
        return plans, mems, starts, peaks, nseg, padded, lengths, jnp

    def test_first_attempt_and_fleet_eval(self):
        from repro.core import first_attempt, fleet_eval
        plans, mems, starts, peaks, nseg, padded, lengths, jnp = \
            self._packed()
        viol, w_succ = first_attempt(
            starts, peaks, padded, lengths, jnp.float32(16.0), dt=1.0)
        # lane 0 is killed (mem 4.5 > 4.0 after t=10); lane 1 is over-
        # provisioned for its whole trace and must succeed on attempt #1
        assert int(viol[0]) == 10 and int(viol[1]) == -1
        np.testing.assert_allclose(float(w_succ[1]), 16 * 1.0, rtol=1e-6)
        w, att, suc = fleet_eval(
            starts, peaks, nseg, padded, lengths, jnp.float32(16.0),
            retry=RetrySpec("ksplus"), dt=1.0)
        for i in range(2):
            res = simulate_execution(
                plans[i], ksplus_retry, mems[i], 1.0, machine_memory=16.0)
            assert int(att[i]) - 1 == res.num_retries
            assert bool(suc[i]) == res.succeeded
            np.testing.assert_allclose(float(w[i]), res.wastage_gbs,
                                       rtol=5e-4)


class TestRetrySpecs:
    def test_all_methods_expose_specs(self):
        for name, method in _method_zoo(MACHINE).items():
            if name == "ks+auto":
                continue  # spec available only after fit (delegates)
            spec = method.retry_spec
            assert isinstance(spec, RetrySpec), name

    def test_concat_packed_pads_k(self):
        a = pack_plans([AllocationPlan(np.zeros(1), np.ones(1))])
        b = pack_plans([AllocationPlan(np.asarray([0.0, 5.0]),
                                       np.asarray([1.0, 2.0]))])
        starts, peaks, nseg = concat_packed([a, b])
        assert starts.shape == (2, 2) and peaks.shape == (2, 2)
        assert list(nseg) == [1, 2]
        assert peaks[0, 1] == peaks[0, 0]  # padded slot holds last peak
